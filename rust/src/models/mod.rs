//! Model registry: the three object-detection workloads of Table 3 and
//! their cost profiles, plus the mapping to AOT artifacts on disk.
//!
//! Two scales coexist by design (DESIGN.md §2):
//! * **Paper scale** — [`CostProfile`] carries Jetson-class work
//!   parameters (GPU/CPU/memory work per frame at 640×640, per-instance
//!   memory footprint). The device simulator consumes these, so simulated
//!   fps/mW land in the paper's ranges.
//! * **Repo scale** — the AOT artifacts are ~1/1000-width JAX/Pallas
//!   detectors actually executed on the PJRT CPU runtime by the serving
//!   path ([`crate::runtime`]).

pub mod manifest;

use std::fmt;
use std::path::{Path, PathBuf};

use crate::util::json::Json;

pub use manifest::{ManifestError, ModelVariant, Precision, VariantManifest};

/// The three evaluation models (paper Table 3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum ModelKind {
    /// YOLOv5-N — 1.9 M params, mAP 27.6.
    Yolo,
    /// FRCNN-MobileNetV3 — 19.4 M params, mAP 32.8.
    Frcnn,
    /// RetinaNet-ResNet50 — 38 M params, mAP 41.5.
    RetinaNet,
}

impl ModelKind {
    pub const ALL: [ModelKind; 3] = [ModelKind::Yolo, ModelKind::Frcnn, ModelKind::RetinaNet];

    /// Artifact / CLI name.
    pub fn name(self) -> &'static str {
        match self {
            ModelKind::Yolo => "yolo",
            ModelKind::Frcnn => "frcnn",
            ModelKind::RetinaNet => "retinanet",
        }
    }

    pub fn parse(s: &str) -> Option<ModelKind> {
        match s.to_ascii_lowercase().as_str() {
            "yolo" | "yolov5-n" | "yolov5n" => Some(ModelKind::Yolo),
            "frcnn" | "frcnn-mobilenetv3" => Some(ModelKind::Frcnn),
            "retinanet" | "retinanet-resnet50" => Some(ModelKind::RetinaNet),
            _ => None,
        }
    }

    /// Paper Table 3: parameter count (millions).
    pub fn params_m(self) -> f64 {
        match self {
            ModelKind::Yolo => 1.9,
            ModelKind::Frcnn => 19.4,
            ModelKind::RetinaNet => 38.0,
        }
    }

    /// Paper Table 3: COCO mAP@0.5:0.95.
    pub fn map(self) -> f64 {
        match self {
            ModelKind::Yolo => 27.6,
            ModelKind::Frcnn => 32.8,
            ModelKind::RetinaNet => 41.5,
        }
    }

    /// Stable small id (hash inputs, CSV columns).
    pub fn id(self) -> u64 {
        match self {
            ModelKind::Yolo => 0,
            ModelKind::Frcnn => 1,
            ModelKind::RetinaNet => 2,
        }
    }

    /// The trivial single-variant manifest of this model (the default
    /// on every device — surfaces stay byte-identical to the
    /// pre-variant model).
    pub fn full_variants(self) -> VariantManifest {
        VariantManifest::full(self)
    }

    /// The standard degraded-variant family of this model (int8 /
    /// reduced-resolution / reduced-depth entries; see
    /// [`VariantManifest::standard`]) — what the accuracy scenarios
    /// and `coral variants` search over.
    pub fn standard_variants(self) -> VariantManifest {
        VariantManifest::standard(self)
    }

    /// Jetson-class cost profile consumed by the device simulator.
    pub fn profile(self) -> CostProfile {
        match self {
            // Calibrated against the paper's anchor points (DESIGN.md §6):
            // NX YOLO tops out ≈ low-40s fps, Orin ≈ 85 fps; FRCNN ≈ 3.6×
            // YOLO's GPU work; RETINANET ≈ 7.5×.
            ModelKind::Yolo => CostProfile {
                gpu_work: 19_000.0,
                cpu_work: 22_000.0,
                mem_work: 9_000.0,
                mem_gb_per_instance: 1.05,
                mem_gb_base: 1.1,
            },
            ModelKind::Frcnn => CostProfile {
                gpu_work: 68_000.0,
                cpu_work: 38_000.0,
                mem_work: 30_000.0,
                mem_gb_per_instance: 1.97,
                mem_gb_base: 1.4,
            },
            ModelKind::RetinaNet => CostProfile {
                gpu_work: 140_000.0,
                cpu_work: 48_000.0,
                mem_work: 62_000.0,
                mem_gb_per_instance: 2.0,
                mem_gb_base: 1.7,
            },
        }
    }
}

impl fmt::Display for ModelKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Work parameters of one model on Jetson-class hardware.
///
/// Units: `*_work` are MHz·ms per frame — dividing by an effective clock
/// in MHz yields a stage time in ms (so they absorb arch-neutral FLOP and
/// byte counts; per-device efficiency lives in
/// [`crate::device::specs::DeviceModelParams`]).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CostProfile {
    /// GPU kernel work per frame.
    pub gpu_work: f64,
    /// CPU pre/post-processing work per frame (per instance thread).
    pub cpu_work: f64,
    /// Memory-subsystem work per frame (weights + activation traffic).
    pub mem_work: f64,
    /// Resident memory per concurrent inference instance (GB).
    pub mem_gb_per_instance: f64,
    /// One-off memory footprint (weights, runtime) (GB).
    pub mem_gb_base: f64,
}

/// One AOT artifact entry from `artifacts/manifest.json`.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactInfo {
    pub model: ModelKind,
    pub batch: usize,
    pub path: PathBuf,
    pub input_shape: [usize; 4],
    pub predictions: usize,
    pub param_count: u64,
    pub flops_per_image: u64,
}

/// Parsed artifact manifest (`make artifacts` output).
#[derive(Debug, Clone, Default)]
pub struct Manifest {
    pub artifacts: Vec<ArtifactInfo>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: &Path) -> anyhow::Result<Manifest> {
        let text = std::fs::read_to_string(dir.join("manifest.json"))?;
        Self::parse(&text, dir)
    }

    /// Parse manifest JSON; artifact paths are resolved against `dir`.
    pub fn parse(text: &str, dir: &Path) -> anyhow::Result<Manifest> {
        let json = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest: {e}"))?;
        let arts = json
            .get("artifacts")
            .and_then(|a| a.as_arr())
            .ok_or_else(|| anyhow::anyhow!("manifest: missing 'artifacts'"))?;
        let mut out = Vec::new();
        for (i, a) in arts.iter().enumerate() {
            let field = |k: &str| {
                a.get(k).ok_or_else(|| anyhow::anyhow!("artifact {i}: missing '{k}'"))
            };
            let model_name = field("model")?.as_str().unwrap_or_default();
            let model = ModelKind::parse(model_name)
                .ok_or_else(|| anyhow::anyhow!("artifact {i}: unknown model '{model_name}'"))?;
            let shape_json = field("input_shape")?
                .as_arr()
                .ok_or_else(|| anyhow::anyhow!("artifact {i}: bad input_shape"))?;
            if shape_json.len() != 4 {
                anyhow::bail!("artifact {i}: input_shape must have 4 dims");
            }
            let mut input_shape = [0usize; 4];
            for (d, v) in shape_json.iter().enumerate() {
                input_shape[d] = v
                    .as_u64()
                    .ok_or_else(|| anyhow::anyhow!("artifact {i}: bad dim"))?
                    as usize;
            }
            out.push(ArtifactInfo {
                model,
                batch: field("batch")?.as_u64().unwrap_or(0) as usize,
                path: dir.join(field("file")?.as_str().unwrap_or_default()),
                input_shape,
                predictions: field("predictions")?.as_u64().unwrap_or(0) as usize,
                param_count: field("param_count")?.as_u64().unwrap_or(0),
                flops_per_image: field("flops_per_image")?.as_u64().unwrap_or(0),
            });
        }
        Ok(Manifest { artifacts: out })
    }

    /// Artifacts of one model, sorted by batch size.
    pub fn for_model(&self, model: ModelKind) -> Vec<&ArtifactInfo> {
        let mut v: Vec<&ArtifactInfo> =
            self.artifacts.iter().filter(|a| a.model == model).collect();
        v.sort_by_key(|a| a.batch);
        v
    }

    /// Supported batch sizes of one model.
    pub fn batches(&self, model: ModelKind) -> Vec<usize> {
        self.for_model(model).iter().map(|a| a.batch).collect()
    }
}

/// Default artifacts directory: `$CORAL_ARTIFACTS` or `./artifacts`.
pub fn artifacts_dir() -> PathBuf {
    std::env::var("CORAL_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|_| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_round_trip() {
        for m in ModelKind::ALL {
            assert_eq!(ModelKind::parse(m.name()), Some(m));
        }
        assert_eq!(ModelKind::parse("YOLOv5-N"), Some(ModelKind::Yolo));
        assert_eq!(ModelKind::parse("bogus"), None);
    }

    #[test]
    fn table3_ordering() {
        // Params and accuracy increase together (paper Table 3).
        let p: Vec<f64> = ModelKind::ALL.iter().map(|m| m.params_m()).collect();
        let a: Vec<f64> = ModelKind::ALL.iter().map(|m| m.map()).collect();
        assert!(p.windows(2).all(|w| w[0] < w[1]));
        assert!(a.windows(2).all(|w| w[0] < w[1]));
        assert!((p[2] / p[0] - 20.0).abs() < 0.1, "20x spread");
    }

    #[test]
    fn profiles_scale_with_model_size() {
        let y = ModelKind::Yolo.profile();
        let f = ModelKind::Frcnn.profile();
        let r = ModelKind::RetinaNet.profile();
        assert!(y.gpu_work < f.gpu_work && f.gpu_work < r.gpu_work);
        assert!(y.mem_gb_per_instance < r.mem_gb_per_instance);
    }

    #[test]
    fn manifest_parse_happy_path() {
        let text = r#"{
          "format": "hlo-text",
          "artifacts": [
            {"model": "yolo", "batch": 2, "file": "yolo_b2.hlo.txt",
             "input_shape": [2, 128, 128, 3], "predictions": 256,
             "param_count": 18613, "flops_per_image": 20856832,
             "sha256": "x", "bytes": 10}
          ]
        }"#;
        let m = Manifest::parse(text, Path::new("/art")).unwrap();
        assert_eq!(m.artifacts.len(), 1);
        let a = &m.artifacts[0];
        assert_eq!(a.model, ModelKind::Yolo);
        assert_eq!(a.batch, 2);
        assert_eq!(a.path, PathBuf::from("/art/yolo_b2.hlo.txt"));
        assert_eq!(a.input_shape, [2, 128, 128, 3]);
        assert_eq!(m.batches(ModelKind::Yolo), vec![2]);
        assert!(m.for_model(ModelKind::Frcnn).is_empty());
    }

    #[test]
    fn manifest_rejects_missing_fields() {
        assert!(Manifest::parse(r#"{"artifacts": [{"model": "yolo"}]}"#, Path::new("."))
            .is_err());
        assert!(Manifest::parse("{}", Path::new(".")).is_err());
        assert!(Manifest::parse("not json", Path::new(".")).is_err());
    }

    #[test]
    fn variant_manifests_wire_through_the_registry() {
        // `models::manifest` is reached through `ModelKind`, not beside
        // it: the registry hands out both families, anchored on its own
        // Table-3 numbers.
        for m in ModelKind::ALL {
            let full = m.full_variants();
            assert!(full.is_singleton());
            assert_eq!(full.model(), m);
            assert_eq!(full.get(0).accuracy, m.map());
            let std = m.standard_variants();
            assert_eq!(std.model(), m);
            assert!(std.len() > 1);
            assert_eq!(std.get(0).accuracy, m.map(), "baseline = Table 3 mAP");
            let worst = std.variants().last().unwrap();
            assert!(worst.accuracy < m.map() && worst.accuracy > 0.0);
            // The degraded profiles feed the same simulator fields the
            // full profile does, just scaled.
            let p = worst.scaled_profile(m);
            assert!(p.gpu_work < m.profile().gpu_work);
            assert!(p.mem_gb_per_instance < m.profile().mem_gb_per_instance);
        }
    }

    #[test]
    fn batches_sorted() {
        let text = r#"{"artifacts": [
            {"model":"yolo","batch":4,"file":"a","input_shape":[4,128,128,3],
             "predictions":256,"param_count":1,"flops_per_image":1},
            {"model":"yolo","batch":1,"file":"b","input_shape":[1,128,128,3],
             "predictions":256,"param_count":1,"flops_per_image":1}
        ]}"#;
        let m = Manifest::parse(text, Path::new(".")).unwrap();
        assert_eq!(m.batches(ModelKind::Yolo), vec![1, 4]);
    }
}
