//! # CORAL — Covariance-Guided Resource Adaptive Learning
//!
//! Production reproduction of *"Covariance-Guided Resource Adaptive
//! Learning for Efficient Edge Inference"* (CS.DC 2026): an online
//! hardware-configuration optimizer for DL inference on edge devices that
//! co-optimizes **throughput and power** using **distance correlation**
//! over a sliding window of online observations — no offline profiling.
//!
//! Front-door documentation: `README.md` (what and why),
//! `ARCHITECTURE.md` (how the pieces compose), `EXPERIMENTS.md`
//! (methodology and expected outcomes).
//!
//! The crate is the L3 layer of a three-layer stack (see `DESIGN.md`):
//!
//! * [`optimizer`] — the paper's contribution (CORAL, Algorithms 1 + 2)
//!   plus every baseline it is evaluated against (ORACLE, ALERT,
//!   ALERT-Online, manufacturer presets).
//! * [`control`] — the closed loop wiring optimizers to measurement: the
//!   [`control::Environment`] trait (sim / live serving / fleet — mixed
//!   NX/Orin fleets included, via the normalized
//!   [`device::NormSpace`] encoding), the canonical
//!   [`control::ControlLoop`] drive engine with drift detection, the
//!   fleet-parallel [`control::FleetRunner`], and the multi-tenant
//!   [`control::TenantArbiter`].
//! * [`coordinator`] — the serving system the optimizer tunes: request
//!   router, dynamic batcher, worker pool honouring the concurrency level.
//! * [`device`] — a faithful simulator of the two NVIDIA Jetson boards
//!   (DVFS config space, analytic power/latency models, config failures).
//! * [`runtime`] — PJRT CPU client executing the AOT-compiled JAX/Pallas
//!   detectors from `artifacts/` on the hot path (python never runs here).
//! * [`telemetry`], [`stats`], [`workload`], [`models`], [`util`] —
//!   substrates built from scratch (tegrastats-like sampling, distance
//!   covariance, Kalman filter, synthetic traffic video, JSON/CSV/PRNG/
//!   property-test/bench harnesses).
//!
//! ## Quickstart
//!
//! ```no_run
//! use coral::control::{ControlLoop, SimEnv};
//! use coral::device::{Device, DeviceKind};
//! use coral::models::ModelKind;
//! use coral::optimizer::{Constraints, CoralOptimizer};
//!
//! let dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 42);
//! let cons = Constraints::dual(30.0, 6500.0); // 30 fps, 6.5 W
//! let opt = CoralOptimizer::new(dev.space().clone(), cons, 42);
//! let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, 10);
//! let outcome = cl.run();
//! let best = outcome.best.expect("feasible configuration found");
//! println!("best = {best:?} (search cost {:.0} s)", outcome.cost_s);
//! ```

pub mod cli;
pub mod control;
pub mod coordinator;
pub mod device;
pub mod experiments;
pub mod models;
pub mod optimizer;
pub mod runtime;
pub mod stats;
pub mod telemetry;
pub mod util;
pub mod workload;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
