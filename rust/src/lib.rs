//! # CORAL — Covariance-Guided Resource Adaptive Learning
//!
//! Production reproduction of *"Covariance-Guided Resource Adaptive
//! Learning for Efficient Edge Inference"* (CS.DC 2026): an online
//! hardware-configuration optimizer for DL inference on edge devices that
//! co-optimizes **throughput and power** using **distance correlation**
//! over a sliding window of online observations — no offline profiling.
//!
//! The crate is the L3 layer of a three-layer stack (see `DESIGN.md`):
//!
//! * [`optimizer`] — the paper's contribution (CORAL, Algorithms 1 + 2)
//!   plus every baseline it is evaluated against (ORACLE, ALERT,
//!   ALERT-Online, manufacturer presets).
//! * [`coordinator`] — the serving system the optimizer tunes: request
//!   router, dynamic batcher, worker pool honouring the concurrency level.
//! * [`device`] — a faithful simulator of the two NVIDIA Jetson boards
//!   (DVFS config space, analytic power/latency models, config failures).
//! * [`runtime`] — PJRT CPU client executing the AOT-compiled JAX/Pallas
//!   detectors from `artifacts/` on the hot path (python never runs here).
//! * [`telemetry`], [`stats`], [`workload`], [`models`], [`util`] —
//!   substrates built from scratch (tegrastats-like sampling, distance
//!   covariance, Kalman filter, synthetic traffic video, JSON/CSV/PRNG/
//!   property-test/bench harnesses).
//!
//! ## Quickstart
//!
//! ```no_run
//! use coral::device::{Device, DeviceKind};
//! use coral::models::ModelKind;
//! use coral::optimizer::{Constraints, CoralOptimizer, Optimizer};
//!
//! let mut dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 42);
//! let cons = Constraints::dual(30.0, 6500.0); // 30 fps, 6.5 W
//! let mut opt = CoralOptimizer::new(dev.space().clone(), cons, 42);
//! for _ in 0..10 {
//!     let cfg = opt.propose();
//!     let m = dev.run(cfg);
//!     opt.observe(cfg, m.throughput_fps, m.power_mw);
//! }
//! let best = opt.best().expect("feasible configuration found");
//! println!("best = {best:?}");
//! ```

pub mod cli;
pub mod coordinator;
pub mod device;
pub mod experiments;
pub mod models;
pub mod optimizer;
pub mod runtime;
pub mod stats;
pub mod telemetry;
pub mod util;
pub mod workload;

/// Crate version (mirrors Cargo.toml).
pub const VERSION: &str = env!("CARGO_PKG_VERSION");
