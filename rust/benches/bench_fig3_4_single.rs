//! Paper Figures 3-4: single-constraint (throughput) comparison, YOLO on
//! both devices. Regenerates results/fig3_4_single.csv and times one
//! 10-iteration CORAL search.
use std::path::Path;
use std::time::Duration;

use coral::device::DeviceKind;
use coral::experiments::{runner, single};
use coral::models::ModelKind;
use coral::optimizer::Constraints;
use coral::util::bench::Bencher;

fn main() {
    single::run(Path::new("results"), 10).expect("single");
    let mut b = Bencher::new(Duration::from_millis(500), 10);
    b.bench("single/coral_10_iters", || {
        runner::run_method(
            runner::MethodKind::Coral,
            DeviceKind::XavierNx,
            ModelKind::Yolo,
            Constraints::max_throughput(),
            7,
        )
        .throughput_fps
    });
}
