//! Fleet-scale bench: per-round wall time and thread-spawn accounting
//! for the persistent [`FleetPool`]-backed `FleetEnv` as fleets grow
//! 10 → 10,000 members (EXPERIMENTS.md §Fleet-scale sweeps).
//!
//! Self-asserting, like every bench here:
//!
//! * **Zero post-construction spawns** — after the warm-up window builds
//!   the pool, `spawned_threads()` never moves again, even at 10,000
//!   members × several rounds.
//! * **Sub-linear scaling** — per-*member* round time at the largest
//!   fleet must be below the smallest fleet's: fixed dispatch overhead
//!   amortizes, so per-round wall time grows sub-linearly in members at
//!   fixed workers.
//! * **Pool ≥ spawn-per-call at N=100** — the pool must not lose to the
//!   old thread-per-member-per-round scheme it replaced (min-of-rounds
//!   comparison, 10% tolerance).
//!
//! Reduced mode for CI: `CORAL_BENCH_FLEET_ROUNDS`,
//! `CORAL_BENCH_FLEET_MAX` (largest member count to run) and
//! `CORAL_BENCH_FLEET_WORKERS` shrink the run. Results are also written
//! machine-readable to `BENCH_fleet_scale.json` (override the path with
//! `CORAL_BENCH_JSON`) so the repo's perf trajectory has data points.

use std::sync::Arc;
use std::time::Instant;

use coral::control::{Environment, FleetEnv};
use coral::device::{Device, HwConfig, NormSpace};
use coral::experiments::scenarios::{FleetScaleScenario, FLEET_SCALE_SCENARIOS};
use coral::util::json::{self, Json};
use coral::util::{table, Rng};

const SEED: u64 = 0xF5CA1E;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

/// Timed measurement rounds per fleet (after one untimed warm-up window
/// that builds the pool).
fn rounds() -> usize {
    env_usize("CORAL_BENCH_FLEET_ROUNDS", 4)
}

/// Largest fleet size to run (reduced CI mode caps this at 1,000).
fn max_members() -> usize {
    env_usize("CORAL_BENCH_FLEET_MAX", 10_000)
}

/// Fixed pool width: the scaling claim is "per-round time sub-linear in
/// members at fixed workers", so every fleet gets the same pool size.
fn workers() -> usize {
    env_usize("CORAL_BENCH_FLEET_WORKERS", 4)
}

struct Outcome {
    scenario: &'static str,
    members: usize,
    best_round_s: f64,
    mean_round_s: f64,
    spawned_threads: u64,
    steals: u64,
    feasible_rounds: usize,
}

/// Drive `rounds()` windows over one pool-backed fleet, asserting the
/// spawn accounting on every round.
fn drive(s: &FleetScaleScenario) -> Outcome {
    let mut fleet = s.fleet(SEED).with_workers(workers());
    let space = fleet.space().clone();
    let cons = s.constraints();
    let mut rng = Rng::new(SEED);
    assert_eq!(fleet.spawned_threads(), 0, "{}: pool is lazy", s.name);
    fleet.measure(space.midpoint()); // warm-up builds the pool
    let spawned = fleet.spawned_threads();
    assert_eq!(spawned, workers() as u64, "{}: pool spawns exactly its workers", s.name);
    let mut best_round_s = f64::INFINITY;
    let mut sum_s = 0.0;
    let mut feasible_rounds = 0;
    for round in 0..rounds() {
        let cfg = space.random(&mut rng);
        let t0 = Instant::now();
        let m = fleet.measure(cfg);
        let dt = t0.elapsed().as_secs_f64();
        best_round_s = best_round_s.min(dt);
        sum_s += dt;
        if cons.feasible(m.throughput_fps, m.power_mw) {
            feasible_rounds += 1;
        }
        assert_eq!(
            fleet.spawned_threads(),
            spawned,
            "{}: round {round} spawned threads after pool construction",
            s.name
        );
    }
    Outcome {
        scenario: s.name,
        members: s.members,
        best_round_s,
        mean_round_s: sum_s / rounds() as f64,
        spawned_threads: fleet.spawned_threads(),
        steals: fleet.pool_steals(),
        feasible_rounds,
    }
}

/// The scheme the pool replaced: spawn one thread per member on every
/// round, rejoin in member order, combine. Same boards, same decode,
/// same proposal sequence as [`drive`] — only the dispatch differs.
fn spawn_per_call_baseline(s: &FleetScaleScenario) -> f64 {
    let kinds = s.kinds();
    let ns = Arc::new(NormSpace::new(kinds.iter().map(|d| d.space()).collect()));
    let mut devices: Vec<Device> = kinds
        .iter()
        .enumerate()
        .map(|(i, &k)| Device::new(k, s.model, SEED + i as u64))
        .collect();
    let space = ns.grid().clone();
    let mut rng = Rng::new(SEED);
    let mut measure = |cfg: HwConfig| {
        let handles: Vec<_> = devices
            .drain(..)
            .enumerate()
            .map(|(i, mut dev)| {
                let ns = Arc::clone(&ns);
                std::thread::spawn(move || {
                    let m = dev.run(ns.decode_for(i, &cfg));
                    (dev, m)
                })
            })
            .collect();
        let mut out = Vec::with_capacity(handles.len());
        for h in handles {
            let (dev, m) = h.join().expect("baseline member panicked");
            devices.push(dev);
            out.push(m);
        }
        FleetEnv::combine(&out)
    };
    measure(space.midpoint()); // mirror the pool side's warm-up window
    let mut best = f64::INFINITY;
    for _ in 0..rounds() {
        let cfg = space.random(&mut rng);
        let t0 = Instant::now();
        measure(cfg);
        best = best.min(t0.elapsed().as_secs_f64());
    }
    best
}

fn main() {
    println!(
        "bench_fleet_scale — {} rounds per fleet, {} pool workers, fleets up to {} members\n",
        rounds(),
        workers(),
        max_members()
    );
    let ran: Vec<FleetScaleScenario> = FLEET_SCALE_SCENARIOS
        .iter()
        .filter(|s| s.members <= max_members())
        .copied()
        .collect();
    let skipped: Vec<&str> = FLEET_SCALE_SCENARIOS
        .iter()
        .filter(|s| s.members > max_members())
        .map(|s| s.name)
        .collect();
    assert!(!ran.is_empty(), "CORAL_BENCH_FLEET_MAX excludes every scenario");
    let outcomes: Vec<Outcome> = ran.iter().map(drive).collect();

    // Sub-linear scaling: fixed dispatch overhead amortizes, so the
    // per-member share of a round must fall as fleets grow.
    if let [first, .., last] = outcomes.as_slice() {
        let small = first.best_round_s / first.members as f64;
        let large = last.best_round_s / last.members as f64;
        assert!(
            large < small,
            "per-round time is not sub-linear in members: {:.3} us/member at {} vs \
             {:.3} us/member at {}",
            large * 1e6,
            last.members,
            small * 1e6,
            first.members
        );
    }

    // Pool vs the spawn-per-call scheme it replaced, at N=100.
    let parity = ran
        .iter()
        .find(|s| s.members == 100)
        .map(|s| (s.name, spawn_per_call_baseline(s)));
    if let Some((name, spawn_best)) = parity {
        let pool_best = outcomes
            .iter()
            .find(|o| o.members == 100)
            .expect("fleet-100 ran")
            .best_round_s;
        assert!(
            pool_best <= spawn_best * 1.10,
            "{name}: pool round {:.3} ms lost to spawn-per-call {:.3} ms",
            pool_best * 1e3,
            spawn_best * 1e3
        );
        println!(
            "N=100 parity: pool best {:.3} ms/round vs spawn-per-call best {:.3} ms/round\n",
            pool_best * 1e3,
            spawn_best * 1e3
        );
    } else {
        println!("N=100 parity check skipped (CORAL_BENCH_FLEET_MAX below 100)\n");
    }

    let mut rows = Vec::new();
    let mut records = Vec::new();
    for o in &outcomes {
        rows.push(vec![
            o.scenario.to_string(),
            o.members.to_string(),
            workers().to_string(),
            o.spawned_threads.to_string(),
            o.steals.to_string(),
            format!("{:.3}", o.best_round_s * 1e3),
            format!("{:.3}", o.mean_round_s * 1e3),
            format!("{:.3}", o.best_round_s * 1e6 / o.members as f64),
            format!("{}/{}", o.feasible_rounds, rounds()),
        ]);
        records.push(json::obj(vec![
            ("scenario", Json::Str(o.scenario.to_string())),
            ("members", Json::Num(o.members as f64)),
            ("workers", Json::Num(workers() as f64)),
            ("rounds", Json::Num(rounds() as f64)),
            ("best_round_s", Json::Num(o.best_round_s)),
            ("mean_round_s", Json::Num(o.mean_round_s)),
            ("spawned_threads", Json::Num(o.spawned_threads as f64)),
            ("steals", Json::Num(o.steals as f64)),
        ]));
    }
    print!(
        "{}",
        table::render(
            &[
                "scenario", "members", "workers", "spawned", "steals", "best ms", "mean ms",
                "us/member", "feasible",
            ],
            &rows
        )
    );
    if !skipped.is_empty() {
        println!(
            "\nskipped above CORAL_BENCH_FLEET_MAX={}: {}",
            max_members(),
            skipped.join(", ")
        );
    }

    let path = std::env::var("CORAL_BENCH_JSON")
        .unwrap_or_else(|_| "BENCH_fleet_scale.json".to_string());
    std::fs::write(&path, Json::Arr(records).to_string_pretty() + "\n")
        .expect("write bench json");
    println!("\nmachine-readable results written to {path}");
    println!(
        "spawned == workers on every row: threads spawn once at pool construction; every \
         later proposal is one O(1)-dispatch index batch plus a sharded combine."
    );
}
