//! Paper Figures 7-8: dual-constraint scenario, FRCNN on both devices.
use std::path::Path;

use coral::experiments::dual;
use coral::models::ModelKind;

fn main() {
    dual::run_model(Path::new("results"), ModelKind::Frcnn, 10).expect("dual frcnn");
}
