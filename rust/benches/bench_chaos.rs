//! Chaos-fleet bench: recovery under injected faults, CORAL and the
//! `TenantArbiter` vs the unarbitrated static baseline
//! (EXPERIMENTS.md §Chaos fleet).
//!
//! Self-asserting, like every bench here:
//!
//! * **CORAL recovers** — driven search → drift-watched hold →
//!   re-search through every `CHAOS_SCENARIOS` fault schedule, every
//!   scheduled event sees a re-feasible window: mean recovery is
//!   finite on all four families.
//! * **The static baseline does not** — the same schedules replayed
//!   against a fixed all-max preset (the PolyThrottle regime; see
//!   PAPERS.md) leave recovery records open forever: the preset either
//!   crashes a member or sits over the fleet budget on every window,
//!   so its mean recovery is infinite.
//! * **Arbitration recovers the shared box** — a thermal-soak +
//!   glitch schedule through a `ChaosEnv`-wrapped `TenantArbiter`
//!   (nx-pair, demand-weighted) re-reaches the combined tenant
//!   targets under the global envelope; the independent baseline
//!   (every controller handed the full envelope) is reported alongside
//!   for the overdraw comparison.
//!
//! Reduced mode for CI: `CORAL_BENCH_CHAOS_EVENTS` keeps only the
//! first N scheduled events per scenario and `CORAL_BENCH_CHAOS_WINDOWS`
//! bounds the driven windows (the run is always extended past the last
//! kept event so recovery stays measurable). Results are also written
//! machine-readable to `BENCH_chaos.json` (override the path with
//! `CORAL_BENCH_JSON`).

use coral::control::{
    drive_coral, drive_static, BudgetPolicy, ChaosEnv, ChaosEvent, ChaosSchedule, Environment,
    GlitchKind,
};
use coral::experiments::scenarios::{ChaosScenario, TenantScenario, CHAOS_SCENARIOS};
use coral::optimizer::Constraints;
use coral::util::json::{self, Json};
use coral::util::table;

const SEED: u64 = 42;
/// Windows kept past the last scheduled fault (rejoin included) so the
/// driver always has room to re-search its way back to feasibility.
const RECOVERY_MARGIN: u64 = 25;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

/// Scheduled events kept per scenario (CI reduction; default: all).
fn events_cap() -> usize {
    env_usize("CORAL_BENCH_CHAOS_EVENTS", usize::MAX)
}

/// Requested driven windows per scenario (default: the scenario's own
/// horizon); always extended to `last event + RECOVERY_MARGIN`.
fn requested_windows(s: &ChaosScenario) -> u64 {
    env_usize("CORAL_BENCH_CHAOS_WINDOWS", s.windows as usize) as u64
}

/// Last window any part of `schedule` touches (a dropout's rejoin
/// lands `down_windows` after the drop).
fn last_fault_window(schedule: &ChaosSchedule) -> u64 {
    schedule
        .events()
        .iter()
        .map(|(w, ev)| match ev {
            ChaosEvent::Dropout { down_windows, .. } => w + down_windows,
            _ => *w,
        })
        .max()
        .expect("non-empty schedule")
}

fn fmt_mean(mean: f64) -> String {
    if mean.is_finite() {
        format!("{mean:.1}")
    } else {
        "∞".to_string()
    }
}

fn main() {
    println!(
        "bench_chaos — events cap {}, recovery margin {RECOVERY_MARGIN} windows\n",
        if events_cap() == usize::MAX { "none".to_string() } else { events_cap().to_string() }
    );

    let mut rows = Vec::new();
    let mut records = Vec::new();

    // ---- CORAL vs the static all-max preset on every fault family.
    for s in &CHAOS_SCENARIOS {
        let schedule = s.schedule(SEED ^ 0x0DD5_EED5).take(events_cap());
        assert!(!schedule.is_empty(), "{}: reduced schedule kept no events", s.name);
        let total = requested_windows(s).max(last_fault_window(&schedule) + RECOVERY_MARGIN);

        let env = ChaosEnv::new(s.fleet(SEED), schedule.clone(), s.constraints());
        let coral = drive_coral(env, s.constraints(), SEED, total);
        assert!(
            coral.all_recovered(),
            "{}: CORAL left events unrecovered: {:?}",
            s.name,
            coral.recoveries()
        );
        let coral_mean = coral.mean_recovery_windows();
        assert!(coral_mean.is_finite(), "{}: CORAL mean recovery not finite", s.name);

        let env = ChaosEnv::new(s.fleet(SEED), schedule, s.constraints());
        let max_cfg = env.space().max_config();
        let fixed = drive_static(env, max_cfg, total);
        assert!(
            !fixed.all_recovered(),
            "{}: the static all-max preset must stay infeasible or over-budget \
             after a fault, yet every record closed",
            s.name
        );
        let static_mean = fixed.mean_recovery_windows();
        assert!(static_mean.is_infinite(), "{}: static mean recovery finite", s.name);

        rows.push(vec![
            s.name.to_string(),
            coral.recoveries().len().to_string(),
            total.to_string(),
            fmt_mean(coral_mean),
            format!("{:.0}", coral.max_recovery_windows().unwrap_or(0.0)),
            fmt_mean(static_mean),
        ]);
        records.push(json::obj(vec![
            ("scenario", Json::Str(s.name.to_string())),
            ("events", Json::Num(coral.recoveries().len() as f64)),
            ("windows", Json::Num(total as f64)),
            ("coral_mean_recovery_windows", Json::Num(coral_mean)),
            (
                "coral_max_recovery_windows",
                Json::Num(coral.max_recovery_windows().unwrap_or(0.0)),
            ),
            ("coral_all_recovered", Json::Bool(coral.all_recovered())),
            ("static_all_recovered", Json::Bool(fixed.all_recovered())),
        ]));
    }
    print!(
        "{}",
        table::render(
            &[
                "scenario", "events", "windows", "coral mean w", "coral max w", "static mean w",
            ],
            &rows
        )
    );

    // ---- Arbitrated shared box vs the independent (unarbitrated) one.
    let ts = TenantScenario::by_name("nx-pair").expect("tenant scenario exists");
    let n = ts.tenants.len() as f64;
    let mean_target: f64 = ts.tenants.iter().map(|t| t.target_fps).sum::<f64>() / n;
    let cons = Constraints::dual(mean_target, ts.global_budget_mw / n);
    let tenant_schedule = || {
        ChaosSchedule::new()
            .at(1, ChaosEvent::ThermalEnable { model: ChaosScenario::thermal_model() })
            .at(3, ChaosEvent::HeatSoak { power_mw: 30_000.0, soak_s: 60.0 })
            .at(5, ChaosEvent::GlitchBurst { windows: 1, kind: GlitchKind::NonFinite })
            .take(events_cap())
    };
    let rounds = last_fault_window(&tenant_schedule()) + 5;
    let mut drive_tenants = |label: &str, arb| {
        let mut env = ChaosEnv::new(arb, tenant_schedule(), cons);
        let probe = env.space().midpoint(); // ignored: each window is one round
        let mut max_overdraw_mw: f64 = 0.0;
        for _ in 0..rounds {
            let m = env.measure(probe);
            max_overdraw_mw = max_overdraw_mw.max(m.power_mw * n - ts.global_budget_mw);
        }
        println!(
            "{}/{label}: {rounds} rounds, mean recovery {} rounds, all recovered: {}, \
             max overdraw {:.0} mW",
            ts.name,
            fmt_mean(env.mean_recovery_windows()),
            env.all_recovered(),
            max_overdraw_mw
        );
        records.push(json::obj(vec![
            ("scenario", Json::Str(format!("{}/{label}", ts.name))),
            ("rounds", Json::Num(rounds as f64)),
            ("mean_recovery_rounds", Json::Num(env.mean_recovery_windows())),
            ("all_recovered", Json::Bool(env.all_recovered())),
            ("max_overdraw_mw", Json::Num(max_overdraw_mw)),
        ]));
        env
    };
    println!();
    let arbitrated = drive_tenants("demand", ts.arbiter(BudgetPolicy::DemandWeighted, SEED));
    assert!(
        arbitrated.all_recovered(),
        "{}: the arbitrated box must re-reach the combined tenant targets \
         under the global envelope: {:?}",
        ts.name,
        arbitrated.recoveries()
    );
    drive_tenants("independent", ts.independent(SEED));

    let path =
        std::env::var("CORAL_BENCH_JSON").unwrap_or_else(|_| "BENCH_chaos.json".to_string());
    std::fs::write(&path, Json::Arr(records).to_string_pretty() + "\n")
        .expect("write bench json");
    println!("\nmachine-readable results written to {path}");
    println!(
        "recovery = windows from each scheduled event to the first measurement that \
         again satisfied the then-current constraints; CORAL re-searches its way back \
         on every family while the static all-max preset never does."
    );
}
