//! Heterogeneous-fleet bench: one **shared** CORAL on the normalized
//! rank-fraction grid vs **independent** per-device CORALs, under a
//! common power envelope (EXPERIMENTS.md §Heterogeneous fleets).
//!
//! For every `HETERO_SCENARIOS` entry, each seed runs both regimes:
//!
//! * shared — one `ControlLoop` over the mixed `FleetEnv` (all boards
//!   measured per window), scored on the fleet-mean constraints;
//! * independent — one `ControlLoop` per board with that board's paper
//!   constraints scaled by the scenario's relaxation
//!   (`HeteroScenario::member_constraints`), so both regimes face the
//!   same aggregate target and the same `N × budget_mw` envelope; a
//!   round counts feasible only when **every** board converged.
//!
//! The headline: the shared search reaches at least the baseline's
//! feasible-round count while consuming a fraction of its measurement
//! cost (one 10-window search for the whole fleet instead of one per
//! device class) — asserted below, like `bench_tenants` asserts its
//! overshoot ordering.

use coral::control::{ControlLoop, Environment, SimEnv};
use coral::device::Device;
use coral::experiments::scenarios::{HeteroScenario, HETERO_SCENARIOS};
use coral::optimizer::CoralOptimizer;
use coral::util::table;

const SEEDS: u64 = 10;
const BUDGET: usize = 10;
const DEVICE_SEED_BASE: u64 = 0xF1EE7;

struct Outcome {
    feasible: bool,
    cost_s: f64,
}

/// Board seeds for round `seed`, member `i`: spaced so rounds draw
/// disjoint boards, and shared by BOTH regimes so the comparison is
/// board-matched (the same chip lottery on each side — only the
/// controller topology differs).
fn board_seed(seed: u64, i: usize) -> u64 {
    DEVICE_SEED_BASE + seed * 31 + i as u64
}

fn shared_round(s: &HeteroScenario, seed: u64) -> Outcome {
    // `fleet()` seeds member i as base + i; pass the round base so the
    // members are exactly the boards `independent_round` drives.
    let fleet = s.fleet(board_seed(seed, 0)).sequential();
    let cons = s.constraints();
    let opt = CoralOptimizer::new(fleet.space().clone(), cons, seed);
    let mut cl = ControlLoop::with_budget(fleet, opt, cons, BUDGET);
    let out = cl.run();
    Outcome {
        feasible: out.best.map(|b| b.feasible).unwrap_or(false),
        cost_s: out.cost_s,
    }
}

fn independent_round(s: &HeteroScenario, seed: u64) -> Outcome {
    let mut feasible = true;
    let mut cost_s = 0.0;
    for (i, &kind) in s.devices.iter().enumerate() {
        let cons = s.member_constraints(i);
        let dev = Device::new(kind, s.model, board_seed(seed, i));
        let opt = CoralOptimizer::new(dev.space().clone(), cons, seed * 31 + i as u64);
        let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, BUDGET);
        let out = cl.run();
        feasible &= out.best.map(|b| b.feasible).unwrap_or(false);
        // Independent searches cannot share windows: total measurement
        // is the sum over boards, not the slowest board.
        cost_s += out.cost_s;
    }
    Outcome { feasible, cost_s }
}

fn main() {
    println!(
        "bench_hetero — shared normalized CORAL vs independent per-device CORALs, \
         {SEEDS} seeds × {BUDGET} iterations\n"
    );
    let mut rows = Vec::new();
    for s in &HETERO_SCENARIOS {
        let shared: Vec<Outcome> = (0..SEEDS).map(|x| shared_round(s, x)).collect();
        let ind: Vec<Outcome> = (0..SEEDS).map(|x| independent_round(s, x)).collect();
        let shared_ok = shared.iter().filter(|o| o.feasible).count();
        let ind_ok = ind.iter().filter(|o| o.feasible).count();
        let mean = |v: &[Outcome]| v.iter().map(|o| o.cost_s).sum::<f64>() / v.len() as f64;
        assert!(
            shared_ok >= ind_ok,
            "{}: shared CORAL ({shared_ok}/{SEEDS} feasible rounds) fell below the \
             independent baseline ({ind_ok}/{SEEDS})",
            s.name
        );
        let boards: Vec<&str> = s.devices.iter().map(|d| d.name()).collect();
        rows.push(vec![
            s.name.to_string(),
            boards.join("+"),
            format!("{}/{}", s.target_fps, s.budget_mw),
            format!("{shared_ok}/{SEEDS}"),
            format!("{ind_ok}/{SEEDS}"),
            format!("{:.0}", mean(&shared)),
            format!("{:.0}", mean(&ind)),
        ]);
    }
    print!(
        "{}",
        table::render(
            &[
                "scenario",
                "fleet",
                "mean fps/mW",
                "shared feasible",
                "indep feasible",
                "shared cost s",
                "indep cost s",
            ],
            &rows
        )
    );
    println!(
        "\nfeasible = the round's chosen configuration met the fleet-mean constraints \
         (shared) / every board met its scaled paper constraints (independent). The \
         shared search measures all boards inside each window, so its cost column is \
         one search; the independent column sums one search per board."
    );
}
