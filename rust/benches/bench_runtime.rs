//! §Perf macro-bench: the real serving hot path — PJRT detector execution
//! per batch size, and end-to-end coordinator throughput/latency at
//! several concurrency levels. Needs `make artifacts`.
use std::time::Duration;

use coral::coordinator::{BatcherConfig, Server, ServerConfig};
use coral::models::{artifacts_dir, Manifest, ModelKind};
use coral::runtime::PjrtRuntime;
use coral::util::bench::Bencher;
use coral::workload::VideoSource;

fn main() {
    let manifest = match Manifest::load(&artifacts_dir()) {
        Ok(m) => m,
        Err(e) => {
            eprintln!("skipping runtime bench (no artifacts: {e})");
            return;
        }
    };
    let rt = PjrtRuntime::cpu().expect("pjrt");
    let mut b = Bencher::new(Duration::from_millis(1500), 10);

    // Kernel-level: PJRT execute per model/batch.
    for model in ModelKind::ALL {
        let m = rt.load_model(&manifest, model).expect("load");
        let side = m.input_side();
        let video = VideoSource::new(side, 30, 9);
        for &batch in &m.batch_sizes() {
            let mut pixels = Vec::new();
            for i in 0..batch {
                pixels.extend_from_slice(&video.frame(i));
            }
            b.bench(&format!("pjrt/{}_b{batch}", model.name()), || {
                m.infer(&pixels, batch).unwrap().len()
            });
        }
    }

    // End-to-end serving at several concurrency levels.
    for c in [1usize, 2, 4] {
        let m = rt.load_model(&manifest, ModelKind::Yolo).expect("load");
        let side = m.input_side();
        let mut server = Server::new(
            m,
            ServerConfig {
                concurrency: c,
                batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) },
            },
        );
        let mut video = VideoSource::new(side, 30, 10);
        let report = server.run_closed_loop(&mut video, 120, 8).expect("serve");
        println!(
            "serve yolo c={c}: {:.1} fps p50={:.1}ms p99={:.1}ms batch={:.2}",
            report.throughput_fps, report.latency_p50_ms, report.latency_p99_ms,
            report.mean_batch
        );
        server.shutdown();
    }
}
