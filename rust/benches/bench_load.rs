//! Open-loop load bench: shed points per policy across `LOAD_SCENARIOS`
//! and the case for `max_batch` as a sixth search dimension
//! (EXPERIMENTS.md §Open-loop load).
//!
//! Self-asserting, like every bench here:
//!
//! * **Batching is load-bearing** — on the noise-free surface there is a
//!   strict SLO+power operating point (one ramp step past the 5-dim
//!   space's shed point) where *no* fixed-`max_batch = 1` config is
//!   feasible — the best 5-dim sweep fails — yet the joint 6-dim CORAL
//!   search finds a feasible config, and its pick batches (`max_batch >
//!   1`).
//! * **Singleton-batch byte-identity** — pinning the batch axis to its
//!   legacy singleton `[1]` leaves same-seed trajectories on the
//!   existing dual scenarios byte-identical to the default (5-dim)
//!   space: identical proposal sequence, identical measurements, every
//!   proposal carrying `max_batch = 1`.
//! * **Shed-point ordering** — every `LOAD_SCENARIOS` policy reports a
//!   finite shed point (the ramp provably vanishes), with CORAL's shed
//!   point ≥ every static preset's on every scenario.
//!
//! Reduced mode for CI: `CORAL_BENCH_LOAD_STEPS` caps the ramp steps per
//! policy, `CORAL_BENCH_LOAD_ITERS` the per-search window budget and
//! `CORAL_BENCH_LOAD_SEEDS` the restart seeds. Results are also written
//! machine-readable to `BENCH_load.json` (override the path with
//! `CORAL_BENCH_JSON`).

use coral::control::{ControlLoop, Environment, SimEnv};
use coral::device::{failure, Device, HwConfig};
use coral::experiments::scenarios::{LoadScenario, DUAL_SCENARIOS, LOAD_SCENARIOS};
use coral::optimizer::{BestConfig, Constraints, CoralOptimizer};
use coral::util::json::{self, Json};
use coral::util::table;
use coral::workload::ArrivalProfile;

const SEED: u64 = 0x10AD;
/// The opened batch axis — the load family's canonical one (powers of
/// two through 4; see the constant's docs for why 8 stays closed).
const BATCH_CAPS: &[u32] = LoadScenario::BATCH_CAPS;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

/// Ramp steps per policy before the CORAL shed scan gives up (full mode
/// is far above any scenario's real shed point, so hitting the cap means
/// reduced mode — finiteness is then asserted on the noise-free oracle
/// ramp instead).
fn max_ramp_steps() -> usize {
    env_usize("CORAL_BENCH_LOAD_STEPS", 40)
}

/// Measurement windows per CORAL search.
fn iters() -> usize {
    env_usize("CORAL_BENCH_LOAD_ITERS", 12)
}

/// Restart seeds per operating point before declaring a rate infeasible
/// for the searched policy.
fn seeds() -> usize {
    env_usize("CORAL_BENCH_LOAD_SEEDS", 3)
}

/// Every valid config of the scenario's board with the batch axis open.
fn valid6(s: &LoadScenario) -> Vec<HwConfig> {
    Device::new(s.device, s.model, SEED)
        .with_batch_caps(BATCH_CAPS.to_vec())
        .space()
        .enumerate()
        .into_iter()
        .filter(|c| failure::check(s.device, s.model, c).is_none())
        .collect()
}

/// One CORAL search on a noise-free board whose windows queue against a
/// steady offered load of `rate` fps, judged by the scenario's SLO+power
/// pair at that rate.
fn coral_best_at(s: &LoadScenario, rate: f64, caps: &[u32], seed: u64) -> Option<BestConfig> {
    let cons = s.constraints_at(rate);
    let dev = Device::new(s.device, s.model, seed)
        .with_batch_caps(caps.to_vec())
        .with_noise_scale(0.0);
    let space = dev.space().clone();
    let env = SimEnv::new(dev).under_load(ArrivalProfile::steady(rate, seed));
    let opt = CoralOptimizer::new(space, cons, seed);
    let mut cl = ControlLoop::with_budget(env, opt, cons, iters());
    cl.run().best
}

/// Feasibility of one config exactly as a live measurement reports it:
/// the noise-free board still applies its per-chip silicon-lottery
/// factors (±3 %), which `LoadScenario::config_feasible_at` — the raw
/// noise-free surface — does not. Near a shed boundary the two views
/// disagree, so searched shed points must be bounded by a *measured*
/// oracle, not the raw one.
fn measured_feasible_at(s: &LoadScenario, cfg: &HwConfig, rate: f64) -> bool {
    let dev = Device::new(s.device, s.model, SEED)
        .with_batch_caps(BATCH_CAPS.to_vec())
        .with_noise_scale(0.0);
    let mut env = SimEnv::new(dev).under_load(ArrivalProfile::steady(rate, SEED));
    let m = env.measure(*cfg);
    s.constraints_at(rate)
        .satisfied(m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy)
}

/// Shed point of a candidate set under the measured (lottery-aware)
/// view — the ceiling for any searched policy, which certifies
/// feasibility through the same measurements.
fn measured_shed_point(s: &LoadScenario, candidates: &[HwConfig], step: f64) -> f64 {
    let mut highest = 0.0;
    let mut rate = s.base_rate_fps;
    while candidates.iter().any(|c| measured_feasible_at(s, c, rate)) {
        highest = rate;
        rate += step;
    }
    highest
}

/// First feasible CORAL outcome across restart seeds, if any.
fn coral_feasible_at(s: &LoadScenario, rate: f64, caps: &[u32]) -> Option<BestConfig> {
    (0..seeds() as u64)
        .filter_map(|k| coral_best_at(s, rate, caps, SEED + k))
        .find(|b| b.feasible)
}

/// CORAL's shed point: climb the ramp until no restart seed finds a
/// feasible config. Returns (shed_fps, hit_step_cap).
fn coral_shed_point(s: &LoadScenario, step: f64) -> (f64, bool) {
    let mut highest = 0.0;
    let mut rate = s.base_rate_fps;
    for _ in 0..max_ramp_steps() {
        if coral_feasible_at(s, rate, BATCH_CAPS).is_none() {
            return (highest, false);
        }
        highest = rate;
        rate += step;
    }
    (highest, true)
}

/// Same-seed trajectory digest on the first dual scenario; `pin_batch`
/// builds the space through `with_batch_caps([1])` instead of the
/// default (legacy) singleton axis.
fn dual_trajectory_digest(pin_batch: bool) -> String {
    let s = DUAL_SCENARIOS[0];
    let cons = Constraints::dual(s.target_fps, s.budget_mw);
    let mut dev = Device::new(s.device, s.model, SEED);
    if pin_batch {
        dev = dev.with_batch_caps(vec![1]);
    }
    let opt = CoralOptimizer::new(dev.space().clone(), cons, SEED);
    let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, 10);
    let out = cl.run();
    for st in &out.trace.steps {
        assert_eq!(st.config.max_batch, 1, "singleton axis proposes only batch=1");
    }
    format!(
        "{:?}",
        out.trace
            .steps
            .iter()
            .map(|st| (st.config, st.throughput_fps, st.power_mw))
            .collect::<Vec<_>>()
    )
}

fn main() {
    println!(
        "bench_load — {} window budget, {} restart seeds, ramp cap {} steps\n",
        iters(),
        seeds(),
        max_ramp_steps()
    );

    // ---- (b) Singleton-batch byte-identity on the existing scenarios.
    let legacy = dual_trajectory_digest(false);
    assert_eq!(
        legacy,
        dual_trajectory_digest(false),
        "same-seed trajectories must be deterministic"
    );
    assert_eq!(
        legacy,
        dual_trajectory_digest(true),
        "pinning the batch axis to [1] must leave same-seed 5-dim trajectories \
         byte-identical"
    );
    println!("singleton-batch byte-identity: OK (same-seed dual trajectory unchanged)\n");

    // ---- (a) The strict pair only batching satisfies, on scenario 0.
    let s0 = &LOAD_SCENARIOS[0];
    let step0 = s0.base_rate_fps * 0.25;
    let all6 = valid6(s0);
    let all5: Vec<HwConfig> = all6.iter().filter(|c| c.max_batch == 1).copied().collect();
    let shed5 = s0.shed_point_fps(&all5, step0);
    let shed6 = s0.shed_point_fps(&all6, step0);
    assert!(
        shed6 > shed5,
        "{}: opening the batch axis must raise the oracle shed point ({shed6} vs {shed5})",
        s0.name
    );
    let probe = shed5 + step0;
    assert!(
        all5.iter().all(|c| !s0.config_feasible_at(c, probe)),
        "{}: the best fixed-max_batch 5-dim sweep must fail at {probe} fps",
        s0.name
    );
    assert!(
        all6.iter().any(|c| s0.config_feasible_at(c, probe)),
        "{}: the 6-dim region must be nonempty at {probe} fps",
        s0.name
    );
    for k in 0..seeds() as u64 {
        let pinned = coral_best_at(s0, probe, &[1], SEED + k);
        assert!(
            pinned.map_or(true, |b| !b.feasible),
            "{}: a batch-pinned search cannot satisfy an empty region (seed {k})",
            s0.name
        );
    }
    let joint = coral_feasible_at(s0, probe, BATCH_CAPS).unwrap_or_else(|| {
        panic!("{}: joint 6-dim CORAL found nothing feasible at {probe} fps", s0.name)
    });
    assert!(
        joint.config.max_batch > 1,
        "{}: the only feasible configs at {probe} fps batch",
        s0.name
    );
    println!(
        "{}: at {probe:.1} fps offered, 5-dim sweep fails exhaustively; joint search \
         serves it with {} (p99 {:.0} ms @ {:.0} mW)\n",
        s0.name, joint.config, joint.p99_latency_ms, joint.power_mw
    );

    // ---- (c) Shed points per policy across the family.
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for s in &LOAD_SCENARIOS {
        let step = s.base_rate_fps * 0.25;
        let all6 = valid6(s);
        let oracle6 = s.shed_point_fps(&all6, step);
        let oracle5 = s.oracle_shed_point_fps(step);
        let measured6 = measured_shed_point(s, &all6, step);
        let (coral_shed, capped) = coral_shed_point(s, step);
        let preset_max = s.shed_point_fps(&[s.device.preset_max_power()], step);
        let preset_def = s.shed_point_fps(&[s.device.preset_default()], step);
        // Finite by construction on the oracle ramps (shed_point_fps
        // terminates only by vanishing); the searched ramp proves the
        // same unless reduced mode capped it first.
        assert!(oracle6.is_finite() && oracle5.is_finite() && measured6.is_finite());
        assert!(coral_shed.is_finite());
        // The raw and measured oracles may disagree by a ramp step near
        // the boundary (silicon lottery, ±3 % on capacity and power) but
        // never wildly.
        assert!(
            (measured6 - oracle6).abs() <= step + 1e-9,
            "{}: measured oracle {measured6} vs raw {oracle6} drifted past one step",
            s.name
        );
        if !capped {
            assert!(
                coral_shed <= measured6,
                "{}: searched shed {coral_shed} beyond the measured oracle {measured6}",
                s.name
            );
        }
        for (label, p) in [("max-power", preset_max), ("default", preset_def)] {
            assert!(
                coral_shed >= p,
                "{}: CORAL shed {coral_shed} below {label} preset's {p}",
                s.name
            );
        }
        assert!(
            coral_shed >= s.base_rate_fps,
            "{}: CORAL must serve at least the base load",
            s.name
        );
        rows.push(vec![
            s.name.to_string(),
            format!("{:.0}", s.base_rate_fps),
            format!("{:.0}ms/{:.0}mW", s.latency_slo_ms, s.budget_mw),
            format!("{:.1}{}", coral_shed, if capped { "+" } else { "" }),
            format!("{measured6:.1}"),
            format!("{oracle6:.1}"),
            format!("{oracle5:.1}"),
            format!("{preset_max:.1}"),
            format!("{preset_def:.1}"),
        ]);
        records.push(json::obj(vec![
            ("scenario", Json::Str(s.name.to_string())),
            ("base_rate_fps", Json::Num(s.base_rate_fps)),
            ("latency_slo_ms", Json::Num(s.latency_slo_ms)),
            ("budget_mw", Json::Num(s.budget_mw)),
            ("shed_coral_fps", Json::Num(coral_shed)),
            ("shed_ramp_capped", Json::Bool(capped)),
            ("shed_oracle_6d_measured_fps", Json::Num(measured6)),
            ("shed_oracle_6d_fps", Json::Num(oracle6)),
            ("shed_oracle_5d_fps", Json::Num(oracle5)),
            ("shed_preset_max_power_fps", Json::Num(preset_max)),
            ("shed_preset_default_fps", Json::Num(preset_def)),
            ("iters", Json::Num(iters() as f64)),
            ("seeds", Json::Num(seeds() as f64)),
        ]));
    }
    print!(
        "{}",
        table::render(
            &[
                "scenario", "base fps", "slo/budget", "coral shed", "meas 6d", "oracle 6d",
                "oracle 5d", "max-power", "default",
            ],
            &rows
        )
    );

    let path =
        std::env::var("CORAL_BENCH_JSON").unwrap_or_else(|_| "BENCH_load.json".to_string());
    std::fs::write(&path, Json::Arr(records).to_string_pretty() + "\n")
        .expect("write bench json");
    println!("\nmachine-readable results written to {path}");
    println!(
        "every policy sheds at a finite offered rate; CORAL (which bootstraps from both \
         presets) never sheds before a static preset, and only the opened batch axis \
         survives past the 5-dim space's shed point."
    );
}
