//! §Perf micro-bench: coordinator primitives on the request path —
//! batcher push/pop, CORAL propose/observe, device-simulator windows —
//! plus the ablation lineup (DESIGN.md §7).
use std::path::Path;
use std::time::Duration;

use coral::control::{ControlLoop, SimEnv};
use coral::coordinator::{Batcher, BatcherConfig, PendingRequest};
use coral::device::{Device, DeviceKind};
use coral::experiments::ablation;
use coral::models::ModelKind;
use coral::optimizer::{Constraints, CoralOptimizer};
use coral::util::bench::Bencher;

fn main() {
    let mut b = Bencher::new(Duration::from_millis(400), 20);

    b.bench("coordinator/batcher_push_pop_batch4", || {
        let mut batcher = Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(10),
        });
        for i in 0..4u64 {
            batcher.push(PendingRequest {
                id: i,
                pixels: Vec::new(),
                arrived: Duration::ZERO,
            });
        }
        batcher.pop_ready(Duration::ZERO).map(|v| v.len())
    });

    b.bench("device/measurement_window", || {
        let mut dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 1);
        let cfg = dev.space().midpoint();
        dev.run(cfg).throughput_fps
    });

    b.bench("coral/control_loop_search_w10", || {
        // The full closed loop (propose → measure → observe × 10) through
        // the canonical engine, including its tracking overhead.
        let dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 1);
        let cons = Constraints::dual(30.0, 6500.0);
        let opt = CoralOptimizer::new(dev.space().clone(), cons, 1);
        let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, 10);
        cl.run().best.map(|b| b.feasible)
    });

    // Design-choice ablations (writes results/ablation.csv).
    ablation::run(Path::new("results"), 10).expect("ablation");
}
