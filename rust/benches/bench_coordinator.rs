//! §Perf micro-bench: coordinator primitives on the request path —
//! batcher push/pop, CORAL propose/observe, device-simulator windows —
//! plus the event-driven pump's idle-overhead audit and the ablation
//! lineup (DESIGN.md §7).
use std::path::Path;
use std::sync::Arc;
use std::time::Duration;

use coral::control::{ControlLoop, SimEnv};
use coral::coordinator::{
    Batcher, BatcherConfig, InferenceEngine, PendingRequest, Server, ServerConfig,
};
use coral::device::{Device, DeviceKind};
use coral::experiments::ablation;
use coral::models::ModelKind;
use coral::optimizer::{Constraints, CoralOptimizer};
use coral::runtime::Detections;
use coral::util::bench::Bencher;
use coral::workload::VideoSource;

/// The retired polling pump's sleep period: the yardstick the
/// event-driven pump is audited against below.
const POLLING_SLEEP_S: f64 = 200e-6;

/// Stub engine standing in for PJRT (absent in offline containers):
/// each batch costs a fixed wall-clock slice, so the pump's own
/// overhead — wakeups per completed frame — is what's measured.
struct StubEngine {
    side: usize,
    per_batch: Duration,
}

impl InferenceEngine for StubEngine {
    fn infer(&self, _pixels: &[f32], n: usize) -> anyhow::Result<Vec<Detections>> {
        std::thread::sleep(self.per_batch);
        Ok(vec![Detections { boxes: Vec::new(), scores: Vec::new() }; n])
    }

    fn input_side(&self) -> usize {
        self.side
    }
}

fn main() {
    let mut b = Bencher::new(Duration::from_millis(400), 20);

    b.bench("coordinator/batcher_push_pop_batch4", || {
        let mut batcher = Batcher::new(BatcherConfig {
            max_batch: 4,
            max_wait: Duration::from_millis(10),
        });
        for i in 0..4u64 {
            batcher.push(PendingRequest {
                id: i,
                pixels: Vec::new(),
                arrived: Duration::ZERO,
            });
        }
        batcher.pop_ready(Duration::ZERO).map(|v| v.len())
    });

    b.bench("device/measurement_window", || {
        let mut dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 1);
        let cfg = dev.space().midpoint();
        dev.run(cfg).throughput_fps
    });

    b.bench("coral/control_loop_search_w10", || {
        // The full closed loop (propose → measure → observe × 10) through
        // the canonical engine, including its tracking overhead.
        let dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 1);
        let cons = Constraints::dual(30.0, 6500.0);
        let opt = CoralOptimizer::new(dev.space().clone(), cons, 1);
        let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, 10);
        cl.run().best.map(|b| b.feasible)
    });

    // Pump idle overhead: wakeups per completed frame for the
    // event-driven pump vs what the old 200 µs-sleep polling pump would
    // have burned over the same wall-clock. Low inflight is the
    // interesting regime — the pump is mostly waiting, which used to
    // mean mostly spinning.
    println!("\npump idle overhead (event-driven vs 200 µs-sleep polling equivalent):");
    println!(
        "  {:>8} {:>8} {:>12} {:>14} {:>16}",
        "inflight", "frames", "wall (s)", "iters/frame", "polling-equiv"
    );
    for inflight in [1usize, 2, 4, 8] {
        let engine = Arc::new(StubEngine { side: 8, per_batch: Duration::from_millis(2) });
        let mut server = Server::with_engine(
            engine,
            ServerConfig {
                concurrency: 2,
                batcher: BatcherConfig {
                    max_batch: 4,
                    max_wait: Duration::from_millis(2),
                },
            },
        );
        let mut video = VideoSource::new(8, 30, 1);
        let frames = 120u64;
        let report = server.run_closed_loop(&mut video, frames, inflight).expect("serve");
        assert_eq!(report.requests, frames);
        let iters_per_frame = report.pump_iterations as f64 / frames as f64;
        let polling_per_frame = report.wall_s / POLLING_SLEEP_S / frames as f64;
        println!(
            "  {:>8} {:>8} {:>12.3} {:>14.2} {:>16.1}",
            inflight, frames, report.wall_s, iters_per_frame, polling_per_frame
        );
        assert!(
            iters_per_frame <= polling_per_frame,
            "event-driven pump must not exceed the polling pump's iterations \
             at inflight={inflight}: {iters_per_frame:.2} vs {polling_per_frame:.1}"
        );
        server.shutdown();
    }

    // Design-choice ablations (writes results/ablation.csv).
    ablation::run(Path::new("results"), 10).expect("ablation");
}
