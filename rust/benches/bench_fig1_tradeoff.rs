//! Paper Figure 1: exhaustive power-throughput sweep of YOLO on both
//! devices. Regenerates results/fig1_*.csv and prints the headline
//! spreads; also times the sweep itself.
use std::path::Path;
use std::time::Duration;

use coral::experiments::fig1;
use coral::util::bench::Bencher;

fn main() {
    let out = Path::new("results");
    fig1::run(out).expect("fig1");
    // Micro: cost of one full exhaustive sweep (the ORACLE's offline
    // burden that CORAL avoids).
    let mut b = Bencher::new(Duration::from_millis(600), 10);
    b.bench("fig1/exhaustive_sweep_nx", || {
        fig1::sweep(coral::device::DeviceKind::XavierNx, 1).points.len()
    });
}
