//! Multi-tenant arbitration bench: arbitrated policies vs independent
//! per-model controllers, scored on **aggregate power overshoot** of the
//! shared box envelope (EXPERIMENTS.md §Multi-tenant arbitration).
//!
//! For every `MULTI_TENANT_SCENARIOS` entry this drives the same tenant
//! mix (same boards, same seeds) under each budget-splitting policy and
//! under the unarbitrated baseline, then reports per-policy aggregate
//! power, max overshoot across rounds, and final-round feasibility. The
//! arbitrated policies must never overshoot more than the baseline, and
//! their sub-budget sums must respect the global envelope on every
//! round (the safety invariant, re-checked here outside the test
//! suite).

use coral::control::{BudgetPolicy, TenantArbiter};
use coral::experiments::scenarios::{TenantScenario, MULTI_TENANT_SCENARIOS};
use coral::util::table;

const DEFAULT_ROUNDS: usize = 3;
const SEED: u64 = 0x7E4A;

/// Rounds per policy; `CORAL_BENCH_ROUNDS` overrides (CI's reduced-mode
/// smoke step runs 1).
fn rounds() -> usize {
    std::env::var("CORAL_BENCH_ROUNDS")
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(DEFAULT_ROUNDS)
}

struct Outcome {
    label: &'static str,
    mean_aggregate_mw: f64,
    max_overshoot_mw: f64,
    feasible_last_round: usize,
}

fn drive(
    label: &'static str,
    s: &TenantScenario,
    mut arb: TenantArbiter,
    arbitrated: bool,
) -> Outcome {
    let reports = arb.run(rounds()).to_vec();
    if arbitrated {
        for r in &reports {
            let sum: f64 = r.tenants.iter().map(|t| t.sub_budget_mw).sum();
            assert!(
                sum <= s.global_budget_mw * (1.0 + 1e-9),
                "{}/{label}: round {} sub-budgets sum {sum:.0} exceed the envelope {}",
                s.name,
                r.round,
                s.global_budget_mw
            );
        }
    }
    let mean_aggregate_mw =
        reports.iter().map(|r| r.aggregate_power_mw).sum::<f64>() / reports.len() as f64;
    let max_overshoot_mw = reports.iter().map(|r| r.overshoot_mw).fold(0.0, f64::max);
    let feasible_last_round = reports
        .last()
        .expect("rounds ran")
        .tenants
        .iter()
        .filter(|t| t.feasible)
        .count();
    Outcome { label, mean_aggregate_mw, max_overshoot_mw, feasible_last_round }
}

fn main() {
    println!(
        "bench_tenants — arbitrated vs independent controllers, {} rounds per policy\n",
        rounds()
    );
    let mut rows = Vec::new();
    for s in &MULTI_TENANT_SCENARIOS {
        let outcomes = [
            drive(
                "static",
                s,
                s.arbiter(BudgetPolicy::Static(s.static_shares()), SEED),
                true,
            ),
            drive("demand", s, s.arbiter(BudgetPolicy::DemandWeighted, SEED), true),
            drive("waterfill", s, s.arbiter(BudgetPolicy::WaterFill, SEED), true),
            drive("independent", s, s.independent(SEED), false),
        ];
        let baseline = outcomes
            .iter()
            .find(|o| o.label == "independent")
            .expect("baseline present")
            .max_overshoot_mw;
        for o in &outcomes {
            if o.label != "independent" {
                assert!(
                    o.max_overshoot_mw <= baseline + 1e-9,
                    "{}/{}: arbitrated overshoot {:.0} mW exceeds the unarbitrated \
                     baseline's {:.0} mW",
                    s.name,
                    o.label,
                    o.max_overshoot_mw,
                    baseline
                );
            }
            rows.push(vec![
                s.name.to_string(),
                o.label.to_string(),
                format!("{:.2}", s.global_budget_mw / 1000.0),
                format!("{:.2}", o.mean_aggregate_mw / 1000.0),
                format!("{:.2}", o.max_overshoot_mw / 1000.0),
                format!("{}/{}", o.feasible_last_round, s.tenants.len()),
            ]);
        }
    }
    print!(
        "{}",
        table::render(
            &["scenario", "policy", "envelope W", "mean box W", "max overshoot W", "feasible"],
            &rows
        )
    );
    println!(
        "\novershoot = max(0, Σ tenant power − envelope) over held allocations; the \
         arbitrated policies cap sub-budget sums at the envelope, the independent baseline \
         hands every controller the full envelope (the PolyThrottle regime)."
    );
}
