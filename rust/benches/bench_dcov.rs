//! §Perf micro-bench: the distance-correlation hot path (recomputed every
//! CORAL iteration over the sliding window). Compares the per-call
//! reference against the fused workspace, across window sizes.
use std::time::Duration;

use coral::stats::dcov::{dcor, DcorWorkspace};
use coral::util::bench::Bencher;
use coral::util::Rng;

fn series(n: usize, seed: u64) -> Vec<f64> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.range_f64(0.0, 100.0)).collect()
}

fn main() {
    let mut b = Bencher::new(Duration::from_millis(400), 20);
    for &w in &[5usize, 10, 20, 50] {
        let tput = series(w, 1);
        let power = series(w, 2);
        let dims: Vec<Vec<f64>> = (0..5).map(|d| series(w, 3 + d)).collect();

        b.bench(&format!("dcov/reference_w{w}_5dims_2metrics"), || {
            let mut acc = 0.0;
            for s in &dims {
                acc += dcor(&tput, s) + dcor(&power, s);
            }
            acc
        });
        let mut ws = DcorWorkspace::new();
        b.bench(&format!("dcov/workspace_w{w}_5dims_2metrics"), || {
            ws.dcor_matrix(&[&tput, &power], &dims)[0][0]
        });
    }
}
