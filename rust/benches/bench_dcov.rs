//! §Perf micro-bench: the distance-correlation hot path (recomputed every
//! CORAL iteration over the sliding window).
//!
//! Three engines are compared (see EXPERIMENTS.md §Perf):
//! * `reference` — per-call O(n²) matrix path (`dcor`), allocates n²;
//! * `workspace` — fused [`DcorWorkspace`] call, auto-dispatching to the
//!   matrix path below `FAST_PATH_MIN_N` and the fast engine above it;
//! * `fast` — the exact O(n log n) [`FastDcov`] engine, O(n) scratch.
//!
//! The large-n rows demonstrate the asymptotic win at the fleet window
//! sizes (W = 100 / 1k / 10k, `experiments::scenarios::WINDOW_SCENARIOS`).
//! The matrix reference is capped at n = 2000: beyond that its n×n
//! buffers (3 × n² f64) dominate memory — which is the point. The final
//! lines print the fast engine's actual scratch footprint next to the
//! n×n element count the matrix path would need.
use std::time::Duration;

use coral::stats::dcov::{dcor, DcorWorkspace, FAST_PATH_MIN_N};
use coral::stats::fastdcov::FastDcov;
use coral::util::bench::Bencher;
use coral::util::Rng;

fn series(n: usize, seed: u64) -> Vec<f64> {
    let mut r = Rng::new(seed);
    (0..n).map(|_| r.range_f64(0.0, 100.0)).collect()
}

fn main() {
    let mut b = Bencher::new(Duration::from_millis(400), 20);

    // Paper-scale windows: the fused workspace vs the per-call reference.
    for &w in &[5usize, 10, 20, 50] {
        let tput = series(w, 1);
        let power = series(w, 2);
        let dims: Vec<Vec<f64>> = (0..5).map(|d| series(w, 3 + d as u64)).collect();

        b.bench(&format!("dcov/reference_w{w}_5dims_2metrics"), || {
            let mut acc = 0.0;
            for s in &dims {
                acc += dcor(&tput, s) + dcor(&power, s);
            }
            acc
        });
        let mut ws = DcorWorkspace::new();
        b.bench(&format!("dcov/workspace_w{w}_5dims_2metrics"), || {
            ws.dcor_matrix(&[&tput, &power], &dims)[0][0]
        });
    }

    // Large-n single-pair rows: O(n²) matrix vs O(n log n) engine. One
    // budget-bounded Bencher per engine family keeps wall time sane.
    let mut lb = Bencher::new(Duration::from_millis(250), 8);
    for &n in &[256usize, 1000, 2000] {
        let x = series(n, 11);
        let y = series(n, 12);
        lb.bench(&format!("dcov/matrix_pair_n{n}"), || dcor(&x, &y));
        let mut eng = FastDcov::new();
        lb.bench(&format!("dcov/fast_pair_n{n}"), || eng.dcor_pair(&x, &y));
    }
    // Beyond the matrix path's practical range: fast engine only.
    {
        let n = 10_000usize;
        let x = series(n, 13);
        let y = series(n, 14);
        let mut eng = FastDcov::new();
        lb.bench(&format!("dcov/fast_pair_n{n}"), || eng.dcor_pair(&x, &y));
    }

    // The optimizer-shaped call at fleet window sizes (2 metrics × 5
    // dims), through the auto-dispatching workspace.
    for &w in &[100usize, 1000, 10_000] {
        let tput = series(w, 21);
        let power = series(w, 22);
        let dims: Vec<Vec<f64>> = (0..5).map(|d| series(w, 23 + d as u64)).collect();
        let mut ws = DcorWorkspace::new();
        lb.bench(&format!("dcov/workspace_fastpath_w{w}"), || {
            ws.dcor_matrix(&[&tput, &power], &dims)[0][0]
        });
    }

    // Memory audit: fast-path scratch vs the n×n the matrix path needs.
    for &n in &[1000usize, 10_000] {
        let x = series(n, 31);
        let y = series(n, 32);
        let mut eng = FastDcov::new();
        let d = eng.dcor_pair(&x, &y);
        println!(
            "mem  dcov/fast_n{n}: scratch={} f64-elems vs matrix n^2={} (dcor={d:.4}, threshold n>={})",
            eng.scratch_elems(),
            n * n,
            FAST_PATH_MIN_N
        );
    }
}
