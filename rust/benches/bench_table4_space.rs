//! Paper Table 4: valid-configuration counts per (model, device).
//! Regenerates results/table4.csv and times the validity filter.
use std::path::Path;
use std::time::Duration;

use coral::device::{failure, DeviceKind};
use coral::models::ModelKind;
use coral::util::bench::Bencher;

fn main() {
    coral::experiments::table4::run(Path::new("results")).expect("table4");
    let mut b = Bencher::new(Duration::from_millis(400), 10);
    b.bench("table4/validity_filter_nx_retinanet", || {
        failure::valid_count(DeviceKind::XavierNx, ModelKind::RetinaNet)
    });
}
