//! Variant-axis bench: the accuracy trade-off across `ACCURACY_SCENARIOS`
//! and the case for the model variant as a seventh search dimension
//! (EXPERIMENTS.md §Accuracy trade-off).
//!
//! Self-asserting, like every bench here:
//!
//! * **Degradation is load-bearing** — on every accuracy scenario's
//!   noise-free surface *no* full-model (variant 0) configuration
//!   satisfies the throughput+power pair, so both manufacturer presets
//!   and the best fixed-full-accuracy sweep fail, and a CORAL search
//!   over the legacy 6-dim space never reports a feasible best; yet the
//!   joint 7-dim search (variant axis open to the standard manifest)
//!   finds a measured-feasible configuration, and its pick serves a
//!   degraded rung that still clears the scenario's mAP floor.
//! * **Arbitrated degradation** — on `nx-pair-accuracy` the fixed-model
//!   arbiter starves its YOLO tenant every round (sub-budget below the
//!   full model's need → floor fallback), while the variant-equipped
//!   arbiter reaches a round where *both* tenants are feasible, the
//!   YOLO tenant serving `variant > 0` inside its 24.0-mAP floor — the
//!   accuracy axis absorbs the contention instead of a tenant's
//!   throughput.
//! * **Singleton-variant byte-identity** — pinning the variant axis to
//!   the explicit identity manifest (`VariantManifest::full`) leaves
//!   same-seed trajectories on the existing dual scenarios
//!   byte-identical to the default space: identical proposal sequence,
//!   identical measurements, every proposal carrying `variant = 0`.
//!
//! Reduced mode for CI: `CORAL_BENCH_VARIANT_ROUNDS` caps the
//! arbitration rounds, `CORAL_BENCH_VARIANT_ITERS` the per-search
//! window budget and `CORAL_BENCH_VARIANT_SEEDS` the restart seeds.
//! Results are also written machine-readable to `BENCH_variants.json`
//! (override the path with `CORAL_BENCH_JSON`).

use coral::control::{BudgetPolicy, ControlLoop, SimEnv, TenantArbiter};
use coral::device::Device;
use coral::experiments::scenarios::{
    AccuracyScenario, ACCURACY_SCENARIOS, ACCURACY_TENANT_SCENARIO, DUAL_SCENARIOS,
};
use coral::models::VariantManifest;
use coral::optimizer::{BestConfig, Constraints, CoralOptimizer};
use coral::util::json::{self, Json};
use coral::util::table;

const SEED: u64 = 0xACC;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key)
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

/// Arbitration rounds in the tenant leg. Each round is an independent
/// deterministically re-seeded search, so more rounds widen the
/// variant-equipped arbiter's chance to settle — the assertions below
/// quantify over "some round", never a specific one.
fn rounds() -> usize {
    env_usize("CORAL_BENCH_VARIANT_ROUNDS", 3)
}

/// Measurement windows per CORAL search in the single-board leg. The
/// accuracy scenarios bind the mAP floor at a *middle* rung, so the
/// search must escape the highest-throughput rung its reward anchor
/// favours — that takes coordinated (variant + DVFS) moves the
/// collision nudges only reach after the anchor's neighbourhood is
/// exhausted. 50 windows covers every scenario.
fn iters() -> usize {
    env_usize("CORAL_BENCH_VARIANT_ITERS", 50)
}

/// Restart seeds per scenario before declaring a search outcome.
fn seeds() -> usize {
    env_usize("CORAL_BENCH_VARIANT_SEEDS", 3)
}

/// One CORAL search over the scenario's 7-dim variant-equipped board.
/// Noise-free like every searched bench leg (the ±3 % silicon lottery
/// still applies — feasibility is certified through the same measured
/// view the search observes).
fn coral_best_7d(s: &AccuracyScenario, seed: u64) -> Option<BestConfig> {
    let cons = s.constraints();
    let dev = Device::new(s.device, s.model, seed)
        .with_variants(s.manifest())
        .with_noise_scale(0.0);
    let opt = CoralOptimizer::new(dev.space().clone(), cons, seed);
    let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, iters());
    cl.run().best
}

/// The same search on the legacy fixed-full-accuracy board (singleton
/// variant axis, same constraints — the mAP floor is trivially met, the
/// throughput+power pair is what full accuracy cannot satisfy).
fn coral_best_fixed(s: &AccuracyScenario, seed: u64) -> Option<BestConfig> {
    let cons = s.constraints();
    let dev = Device::new(s.device, s.model, seed).with_noise_scale(0.0);
    let opt = CoralOptimizer::new(dev.space().clone(), cons, seed);
    let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, iters());
    cl.run().best
}

/// The contended pair on noise-free boards (deterministically
/// verifiable; the scenario's own `arbiter_variants` builder — noisy
/// boards, same seeds — is exercised by the scenario tests and the
/// CLI). `variants` opens each tenant's standard ladder.
fn accuracy_arbiter(variants: bool) -> TenantArbiter {
    let s = &ACCURACY_TENANT_SCENARIO;
    // 60 windows per round: the YOLO tenant's degraded region sits at
    // low GPU frequencies on rungs 1–2, far from the high-throughput
    // rung-3 anchor the reward favours, so the default 10-window round
    // never reaches it (the fixed run parks either way).
    let mut arb =
        TenantArbiter::new(s.global_budget_mw, BudgetPolicy::DemandWeighted).budget_iters(60);
    for (i, t) in s.tenants.iter().enumerate() {
        let mut dev =
            Device::new(s.device, t.model, SEED + i as u64).with_noise_scale(0.0);
        if variants {
            dev = dev.with_variants(t.model.standard_variants());
        }
        arb.add_tenant(*t, Box::new(SimEnv::new(dev)), SEED + 100 + i as u64);
    }
    arb
}

/// Same-seed trajectory digest on the first dual scenario;
/// `explicit_manifest` builds the board through an explicit
/// `VariantManifest::full` instead of the default singleton axis.
fn dual_trajectory_digest(explicit_manifest: bool) -> String {
    let s = DUAL_SCENARIOS[0];
    let cons = Constraints::dual(s.target_fps, s.budget_mw);
    let mut dev = Device::new(s.device, s.model, SEED);
    if explicit_manifest {
        dev = dev.with_variants(VariantManifest::full(s.model));
    }
    let opt = CoralOptimizer::new(dev.space().clone(), cons, SEED);
    let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, 10);
    let out = cl.run();
    for st in &out.trace.steps {
        assert_eq!(st.config.variant, 0, "singleton axis proposes only variant 0");
    }
    format!(
        "{:?}",
        out.trace
            .steps
            .iter()
            .map(|st| (st.config, st.throughput_fps, st.power_mw))
            .collect::<Vec<_>>()
    )
}

fn main() {
    println!(
        "bench_variants — {} window budget, {} restart seeds, {} arbitration round(s)\n",
        iters(),
        seeds(),
        rounds()
    );

    // ---- (c) Singleton-variant byte-identity on the existing scenarios.
    let legacy = dual_trajectory_digest(false);
    assert_eq!(
        legacy,
        dual_trajectory_digest(false),
        "same-seed trajectories must be deterministic"
    );
    assert_eq!(
        legacy,
        dual_trajectory_digest(true),
        "an explicit identity manifest must leave same-seed 6-dim trajectories \
         byte-identical"
    );
    println!("singleton-variant byte-identity: OK (same-seed dual trajectory unchanged)\n");

    // ---- (a) The accuracy trade-off on every single-board scenario.
    let mut rows = Vec::new();
    let mut records = Vec::new();
    for s in &ACCURACY_SCENARIOS {
        let manifest = s.manifest();
        let grid = s.device.space().with_variant_axis(manifest.len()).enumerate();
        let full_feasible = grid
            .iter()
            .filter(|c| c.variant == 0 && s.config_feasible(c))
            .count();
        let degraded_feasible = grid
            .iter()
            .filter(|c| c.variant > 0 && s.config_feasible(c))
            .count();
        assert_eq!(
            full_feasible, 0,
            "{}: the full model must be infeasible at {} fps inside {} mW",
            s.name, s.target_fps, s.budget_mw
        );
        assert!(
            degraded_feasible > 0,
            "{}: some degraded rung must open a feasible region",
            s.name
        );
        for (label, preset) in [
            ("max-power", s.device.preset_max_power()),
            ("default", s.device.preset_default()),
        ] {
            assert!(
                !s.config_feasible(&preset),
                "{}: the {label} preset serves the full model and must fail",
                s.name
            );
        }
        // The fixed-full-accuracy search has an empty region to satisfy.
        for k in 0..seeds() as u64 {
            let fixed = coral_best_fixed(s, SEED + k);
            assert!(
                fixed.map_or(true, |b| !b.feasible),
                "{}: a fixed-full-accuracy search cannot satisfy an empty region (seed {k})",
                s.name
            );
        }
        // The joint 7-dim search finds the region the manifest opened.
        let best = (0..seeds() as u64)
            .filter_map(|k| coral_best_7d(s, SEED + k))
            .find(|b| b.feasible)
            .unwrap_or_else(|| {
                panic!("{}: joint 7-dim CORAL found nothing feasible", s.name)
            });
        let v = manifest.get(best.config.variant);
        assert!(
            best.config.variant > 0,
            "{}: only degraded rungs are feasible, yet CORAL picked variant 0",
            s.name
        );
        assert!(
            v.accuracy >= s.min_accuracy,
            "{}: CORAL's rung ({}) must clear the {:.1}-mAP floor",
            s.name,
            v.label(),
            s.min_accuracy
        );
        rows.push(vec![
            s.name.to_string(),
            format!("{:.0}fps/{:.0}mW/{:.1}mAP", s.target_fps, s.budget_mw, s.min_accuracy),
            full_feasible.to_string(),
            degraded_feasible.to_string(),
            v.label(),
            format!("{:.1}", best.throughput_fps),
            format!("{:.0}", best.power_mw),
            format!("{:.1}", best.accuracy),
        ]);
        records.push(json::obj(vec![
            ("scenario", Json::Str(s.name.to_string())),
            ("target_fps", Json::Num(s.target_fps)),
            ("budget_mw", Json::Num(s.budget_mw)),
            ("min_accuracy_map", Json::Num(s.min_accuracy)),
            ("full_feasible_cfgs", Json::Num(full_feasible as f64)),
            ("degraded_feasible_cfgs", Json::Num(degraded_feasible as f64)),
            ("coral_variant", Json::Str(v.label())),
            ("coral_fps", Json::Num(best.throughput_fps)),
            ("coral_power_mw", Json::Num(best.power_mw)),
            ("coral_accuracy_map", Json::Num(best.accuracy)),
            ("iters", Json::Num(iters() as f64)),
            ("seeds", Json::Num(seeds() as f64)),
        ]));
    }
    print!(
        "{}",
        table::render(
            &[
                "scenario", "constraints", "full cfgs", "degraded cfgs", "coral rung",
                "fps", "mW", "mAP",
            ],
            &rows
        )
    );

    // ---- (b) Arbitrated degradation on the contended pair.
    let s = &ACCURACY_TENANT_SCENARIO;
    println!(
        "\n{}: {:.1} W envelope, fixed vs variants, {} round(s)",
        s.name,
        s.global_budget_mw / 1000.0,
        rounds()
    );
    let mut fixed = accuracy_arbiter(false);
    let mut variants = accuracy_arbiter(true);
    fixed.run(rounds());
    variants.run(rounds());
    let yolo = s.tenants[0].name;
    let floor = s.tenants[0].min_accuracy.expect("the YOLO tenant carries a floor");
    // Fixed arbiter: the YOLO tenant's sub-budget cannot carry the full
    // model, so it parks at the floor (starves) every single round.
    for r in fixed.history() {
        let t = r.tenants.iter().find(|t| t.name == yolo).expect("tenant present");
        assert!(
            t.fell_back || !t.feasible,
            "{}: round {} — the fixed arbiter cannot make the YOLO tenant feasible",
            s.name,
            r.round
        );
        assert!(
            r.overshoot_mw == 0.0,
            "{}: round {} — parking must not blow the envelope",
            s.name,
            r.round
        );
    }
    // Variant arbiter: some round settles with every tenant feasible and
    // the YOLO tenant serving a degraded rung inside its floor.
    let manifest = s.tenants[0].model.standard_variants();
    let settled = variants
        .history()
        .iter()
        .find(|r| {
            let y = r.tenants.iter().find(|t| t.name == yolo).expect("tenant present");
            r.tenants.iter().all(|t| t.feasible)
                && y.chosen.config.variant > 0
                && r.overshoot_mw == 0.0
        })
        .unwrap_or_else(|| {
            panic!(
                "{}: no round settled with both tenants feasible and the YOLO \
                 tenant degraded",
                s.name
            )
        });
    let y = settled.tenants.iter().find(|t| t.name == yolo).expect("tenant present");
    let rung = manifest.get(y.chosen.config.variant);
    assert!(
        rung.accuracy >= floor,
        "{}: the degraded rung ({}) must clear the tenant's {:.1}-mAP floor",
        s.name,
        rung.label(),
        floor
    );
    let mut trows = Vec::new();
    for (run, arb) in [("fixed", &fixed), ("variants", &variants)] {
        for r in arb.history() {
            for t in &r.tenants {
                trows.push(vec![
                    r.round.to_string(),
                    run.to_string(),
                    t.name.to_string(),
                    if run == "variants" {
                        t.model.standard_variants().get(t.chosen.config.variant).label()
                    } else {
                        "fixed".to_string()
                    },
                    format!("{:.1}", t.chosen.throughput_fps),
                    format!("{:.0}", t.chosen.power_mw),
                    format!("{:.1}", t.chosen.accuracy),
                    if t.fell_back {
                        "floor".into()
                    } else if t.feasible {
                        "ok".into()
                    } else {
                        "infeas".into()
                    },
                ]);
            }
        }
    }
    print!(
        "{}",
        table::render(
            &["round", "run", "tenant", "variant", "fps", "mW", "mAP", "state"],
            &trows
        )
    );
    println!(
        "round {}: both tenants feasible, {} serving {} ({:.1} mAP ≥ {:.1} floor)",
        settled.round,
        yolo,
        rung.label(),
        rung.accuracy,
        floor
    );
    records.push(json::obj(vec![
        ("scenario", Json::Str(s.name.to_string())),
        ("global_budget_mw", Json::Num(s.global_budget_mw)),
        ("rounds", Json::Num(rounds() as f64)),
        ("settled_round", Json::Num(settled.round as f64)),
        ("yolo_variant", Json::Str(rung.label())),
        ("yolo_accuracy_map", Json::Num(rung.accuracy)),
        ("yolo_accuracy_floor_map", Json::Num(floor)),
        ("singleton_byte_identity", Json::Bool(true)),
    ]));

    let path =
        std::env::var("CORAL_BENCH_JSON").unwrap_or_else(|_| "BENCH_variants.json".to_string());
    std::fs::write(&path, Json::Arr(records).to_string_pretty() + "\n")
        .expect("write bench json");
    println!("\nmachine-readable results written to {path}");
    println!(
        "accuracy is a spendable resource: every scenario's full model is provably \
         infeasible, every preset and fixed-accuracy search fails with it, and only \
         the opened variant axis — bounded by the mAP floor — carries the target."
    );
}
