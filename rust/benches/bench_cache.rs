//! Measurement-cache bench: a repeat-heavy sweep through [`CachedEnv`]
//! vs the same sweep uncached (EXPERIMENTS.md §Measurement cache).
//!
//! Part 1 re-runs the same (device, model, seed) CORAL search several
//! times on **noise-free** boards — the repeat-heavy regime a fleet
//! replaying its standard scenario set lives in. Noise-free surfaces
//! make the cached and uncached trajectories bit-comparable, so the
//! bench can assert the cache's contract, not just time it: identical
//! final outcomes, strictly fewer real measurement windows, strictly
//! lower total `cost_s`.
//!
//! Part 2 repeats the noisy [`fleet_sweep_cached`] over one shared
//! store: pass 2 replays every window as a hit at zero measurement
//! cost with per-scenario stats identical to pass 1.
//!
//! `CORAL_BENCH_PASSES` / `CORAL_BENCH_SEEDS` shrink the sweep for
//! CI's reduced-mode smoke step.

use coral::control::{
    fleet_sweep_cached, CacheStore, CachedEnv, ControlLoop, Environment, FleetRunner,
    LoopOutcome, SimEnv, DEFAULT_BUDGET,
};
use coral::device::Device;
use coral::experiments::scenarios::{DualScenario, DUAL_SCENARIOS};
use coral::optimizer::{Constraints, CoralOptimizer};
use coral::util::table;

const DEVICE_SEED: u64 = 0xCAC4E;
const OPT_SEED: u64 = 11;

fn env_or(name: &str, default: usize) -> usize {
    std::env::var(name)
        .ok()
        .and_then(|v| v.parse().ok())
        .filter(|&n| n >= 1)
        .unwrap_or(default)
}

/// One CORAL search over `env` — the paper's iteration budget, fixed
/// optimizer seed, so every pass proposes the same trajectory.
fn run_once<E: Environment>(env: E, s: &DualScenario) -> LoopOutcome {
    let cons = Constraints::dual(s.target_fps, s.budget_mw);
    let opt = CoralOptimizer::new(env.space().clone(), cons, OPT_SEED);
    ControlLoop::with_budget(env, opt, cons, DEFAULT_BUDGET).run()
}

/// The scenario's board with measurement noise off: reads depend only
/// on the applied configuration, so cached and uncached runs are
/// bit-comparable.
fn quiet_board(s: &DualScenario) -> Device {
    Device::new(s.device, s.model, DEVICE_SEED).with_noise_scale(0.0)
}

/// Outcome digest for byte-identity assertions across passes/modes.
fn digest(out: &LoopOutcome) -> String {
    format!(
        "{:?}|{:?}|{:?}",
        out.best, out.first_feasible_iter, out.feasible_by_iter
    )
}

fn main() {
    let passes = env_or("CORAL_BENCH_PASSES", 5);
    let seeds = env_or("CORAL_BENCH_SEEDS", 4) as u64;
    println!(
        "bench_cache — repeat-heavy sweeps, cached vs uncached ({passes} passes, \
         {} scenarios)\n",
        DUAL_SCENARIOS.len()
    );

    // --- Part 1: same search repeated on noise-free boards -------------
    let mut rows = Vec::new();
    let mut total_uncached_windows = 0u64;
    let mut total_real_windows = 0u64;
    for s in &DUAL_SCENARIOS {
        // Uncached reference: every pass re-measures every window on a
        // fresh board.
        let mut uncached_cost = 0.0;
        let mut uncached_windows = 0u64;
        let mut reference = None;
        for _ in 0..passes {
            let board = quiet_board(s);
            let out = run_once(SimEnv::new(board), s);
            uncached_cost += out.cost_s;
            uncached_windows += out.iters as u64;
            let d = digest(&out);
            match &reference {
                None => reference = Some(d),
                Some(r) => assert_eq!(
                    r, &d,
                    "{}/{}: noise-free passes must repeat exactly",
                    s.device, s.model
                ),
            }
        }
        let reference = reference.expect("at least one pass");

        // Cached: fresh board each pass, one shared store. Pass 1 pays
        // for unseen configurations; later passes replay from the store.
        let store = CacheStore::new();
        let mut cached_cost = 0.0;
        for pass in 0..passes {
            let board = quiet_board(s);
            let env = CachedEnv::with_store(SimEnv::new(board), store.clone());
            let out = run_once(env, s);
            cached_cost += out.cost_s;
            assert_eq!(
                digest(&out),
                reference,
                "{}/{}: cached pass {pass} diverged from the uncached run",
                s.device,
                s.model
            );
            if pass > 0 {
                assert_eq!(out.cost_s, 0.0, "repeat passes replay entirely from the store");
            }
        }
        let st = store.stats();
        let real = st.misses + st.refreshes;
        assert!(
            real < uncached_windows,
            "{}/{}: cached sweep must run strictly fewer real windows \
             ({real} vs {uncached_windows})",
            s.device,
            s.model
        );
        assert!(
            cached_cost < uncached_cost,
            "{}/{}: cached cost {cached_cost:.0}s not below uncached {uncached_cost:.0}s",
            s.device,
            s.model
        );
        total_uncached_windows += uncached_windows;
        total_real_windows += real;
        rows.push(vec![
            s.device.name().to_string(),
            s.model.name().to_string(),
            uncached_windows.to_string(),
            real.to_string(),
            st.hits.to_string(),
            format!("{:.0}%", st.hit_rate() * 100.0),
            st.windows_saved().to_string(),
            format!("{uncached_cost:.0}s"),
            format!("{cached_cost:.0}s"),
            format!("{:.0}s", st.cost_saved_s),
        ]);
    }
    print!(
        "{}",
        table::render(
            &[
                "device", "model", "uncached w", "real w", "hits", "hit rate", "saved w",
                "uncached cost", "cached cost", "saved",
            ],
            &rows
        )
    );
    println!(
        "\nidentical outcomes on every pass; {total_real_windows} real windows instead of \
         {total_uncached_windows} ({:.1}x fewer)",
        total_uncached_windows as f64 / total_real_windows as f64
    );

    // --- Part 2: noisy fleet sweep replayed from a shared store --------
    let runner = FleetRunner::auto();
    let store = CacheStore::new();
    let scenarios = &DUAL_SCENARIOS[..2];
    let p1 = fleet_sweep_cached(scenarios, seeds, &runner, &store);
    let misses_p1 = store.stats().misses;
    let p2 = fleet_sweep_cached(scenarios, seeds, &runner, &store);
    assert_eq!(store.stats().misses, misses_p1, "pass 2 runs zero real windows");
    for (a, b) in p1.iter().zip(&p2) {
        assert_eq!(a.feasible, b.feasible, "replayed outcomes identical");
        assert_eq!(b.mean_cost_s, 0.0, "pass-2 windows all hit the store");
    }
    let st = store.stats();
    println!(
        "\nfleet_sweep_cached ({} scenarios x {seeds} seeds, 2 passes): {} real windows, \
         {} hits, pass-2 cost 0s — {:.0} simulated seconds of measurement saved",
        scenarios.len(),
        st.misses,
        st.hits,
        st.cost_saved_s
    );
}
