//! Paper Figures 9-10: dual-constraint scenario, RETINANET on both
//! devices (the paper's hardest case: every baseline fails).
use std::path::Path;

use coral::experiments::dual;
use coral::models::ModelKind;

fn main() {
    dual::run_model(Path::new("results"), ModelKind::RetinaNet, 10).expect("dual retinanet");
}
