//! Paper Figures 5-6: dual-constraint scenario, YOLO on both devices.
use std::path::Path;
use std::time::Duration;

use coral::experiments::dual;
use coral::experiments::runner::{run_method, MethodKind};
use coral::experiments::scenarios::dual_constraints;
use coral::device::DeviceKind;
use coral::models::ModelKind;
use coral::util::bench::Bencher;

fn main() {
    dual::run_model(Path::new("results"), ModelKind::Yolo, 10).expect("dual yolo");
    let mut b = Bencher::new(Duration::from_millis(500), 10);
    b.bench("dual_yolo/coral_10_iters_nx", || {
        run_method(
            MethodKind::Coral,
            DeviceKind::XavierNx,
            ModelKind::Yolo,
            dual_constraints(DeviceKind::XavierNx, ModelKind::Yolo),
            3,
        )
        .feasible
    });
}
