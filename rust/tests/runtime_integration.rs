//! Integration: PJRT runtime + serving coordinator over the real AOT
//! artifacts (`make artifacts`).
//!
//! These tests need two things the offline container may lack: the AOT
//! artifact bundle on disk, and a real PJRT backend (the vendored `xla`
//! stub compiles but cannot execute — see vendor/README.md). When either
//! is missing the tests **skip** (pass vacuously, with a note on stderr)
//! instead of failing: tier-1 must stay green everywhere, and the
//! serving logic itself is covered by the pure-logic coordinator tests.

use std::sync::Arc;
use std::time::Duration;

use coral::coordinator::worker::{BatchJob, ShareableRuntime, WorkerPool};
use coral::coordinator::{BatcherConfig, Server, ServerConfig};
use coral::models::{artifacts_dir, Manifest, ModelKind};
use coral::runtime::PjrtRuntime;
use coral::workload::VideoSource;

fn manifest() -> Option<Manifest> {
    let dir = artifacts_dir();
    match Manifest::load(&dir) {
        Ok(m) => Some(m),
        Err(e) => {
            eprintln!(
                "skipping PJRT integration test — no artifacts at {} ({e}); \
                 run `make artifacts` to enable",
                dir.display()
            );
            None
        }
    }
}

/// Manifest + live PJRT runtime, or None (skip) when either is absent.
fn setup() -> Option<(Manifest, PjrtRuntime)> {
    let m = manifest()?;
    match PjrtRuntime::cpu() {
        Ok(rt) => Some((m, rt)),
        Err(e) => {
            eprintln!("skipping PJRT integration test — PJRT unavailable: {e}");
            None
        }
    }
}

#[test]
fn manifest_lists_all_models_and_batches() {
    let Some(m) = manifest() else { return };
    for model in ModelKind::ALL {
        let batches = m.batches(model);
        assert!(!batches.is_empty(), "{model} missing");
        assert!(batches.contains(&1), "{model} needs batch 1");
    }
}

#[test]
fn yolo_infer_shapes_and_determinism() {
    let Some((m, rt)) = setup() else { return };
    let model = rt.load_model(&m, ModelKind::Yolo).unwrap();
    let side = model.input_side();
    let mut video = VideoSource::new(side, 30, 7);
    let frame = video.next_frame();

    let a = model.infer(&frame, 1).unwrap();
    let b = model.infer(&frame, 1).unwrap();
    assert_eq!(a.len(), 1);
    assert_eq!(a[0].boxes.len(), a[0].scores.len());
    assert!(!a[0].boxes.is_empty());
    assert_eq!(a, b, "inference must be deterministic");
    // Scores are probabilities.
    assert!(a[0].scores.iter().all(|s| (0.0..=1.0).contains(s)));
    // Boxes are well-formed (x2 >= x1, y2 >= y1).
    assert!(a[0].boxes.iter().all(|bx| bx[2] >= bx[0] && bx[3] >= bx[1]));
}

#[test]
fn batching_pads_and_truncates_consistently() {
    let Some((m, rt)) = setup() else { return };
    let model = rt.load_model(&m, ModelKind::Yolo).unwrap();
    let side = model.input_side();
    let v = VideoSource::new(side, 30, 3);
    let f0 = v.frame(0);
    let f1 = v.frame(1);
    let f2 = v.frame(2);

    // Batch of 3 → padded to the 4-batch executable; results must match
    // single-image runs.
    let mut pixels = Vec::new();
    pixels.extend_from_slice(&f0);
    pixels.extend_from_slice(&f1);
    pixels.extend_from_slice(&f2);
    let batch = model.infer(&pixels, 3).unwrap();
    assert_eq!(batch.len(), 3);
    for (i, f) in [f0, f1, f2].iter().enumerate() {
        let single = model.infer(f, 1).unwrap();
        for (a, b) in batch[i].scores.iter().zip(&single[0].scores) {
            assert!((a - b).abs() < 1e-4, "image {i}: batch vs single mismatch");
        }
    }
}

#[test]
fn infer_rejects_bad_sizes() {
    let Some((m, rt)) = setup() else { return };
    let model = rt.load_model(&m, ModelKind::Yolo).unwrap();
    assert!(model.infer(&[0.0; 7], 1).is_err());
    assert!(model.infer(&[], 1000).is_err());
    assert!(model.infer(&[], 0).unwrap().is_empty());
}

#[test]
fn worker_pool_runs_concurrent_batches() {
    let Some((m, rt)) = setup() else { return };
    let model = rt.load_model(&m, ModelKind::Yolo).unwrap();
    let side = model.input_side();
    let video = VideoSource::new(side, 30, 5);
    let pool = WorkerPool::new(Arc::new(ShareableRuntime(model)), 3);
    assert_eq!(pool.size(), 3);

    for j in 0..6u64 {
        pool.submit(BatchJob {
            ids: vec![j],
            arrived: vec![Duration::ZERO],
            pixels: video.frame(j as usize),
        });
    }
    let mut got = Vec::new();
    for _ in 0..6 {
        let r = pool.recv_timeout(Duration::from_secs(60)).expect("result");
        assert!(r.error.is_none(), "{:?}", r.error);
        got.extend(r.ids);
    }
    got.sort_unstable();
    assert_eq!(got, (0..6).collect::<Vec<_>>());
    assert!(pool.shutdown().is_empty());
}

#[test]
fn server_closed_loop_serves_and_reports() {
    let Some((m, rt)) = setup() else { return };
    let model = rt.load_model(&m, ModelKind::Yolo).unwrap();
    let side = model.input_side();
    let mut video = VideoSource::new(side, 30, 11);
    let mut server = Server::new(
        model,
        ServerConfig {
            concurrency: 2,
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) },
        },
    );
    let report = server.run_closed_loop(&mut video, 40, 8).unwrap();
    assert_eq!(report.requests, 40);
    assert_eq!(report.failed, 0);
    assert!(report.throughput_fps > 1.0, "fps {}", report.throughput_fps);
    assert!(report.latency_p50_ms > 0.0);
    assert!(report.latency_p99_ms >= report.latency_p50_ms);
    assert!(report.mean_batch >= 1.0);
    assert_eq!(server.shutdown(), 40);
}

#[test]
fn server_live_concurrency_change_loses_nothing() {
    let Some((m, rt)) = setup() else { return };
    let model = rt.load_model(&m, ModelKind::Yolo).unwrap();
    let side = model.input_side();
    let mut video = VideoSource::new(side, 30, 13);
    let mut server = Server::new(model, ServerConfig::default());
    let r1 = server.run_closed_loop(&mut video, 12, 4).unwrap();
    assert_eq!(r1.concurrency, 2);
    server.set_concurrency(4);
    let r2 = server.run_closed_loop(&mut video, 12, 4).unwrap();
    assert_eq!(r2.concurrency, 4);
    assert_eq!(server.shutdown(), 24);
}

#[test]
fn worker_error_path_reports_failure_not_crash() {
    // Failure injection: a malformed job (wrong pixel count) must surface
    // as a BatchResult error, not kill the worker or the pool.
    let Some((m, rt)) = setup() else { return };
    let model = rt.load_model(&m, ModelKind::Yolo).unwrap();
    let side = model.input_side();
    let video = VideoSource::new(side, 30, 21);
    let pool = WorkerPool::new(Arc::new(ShareableRuntime(model)), 1);

    pool.submit(BatchJob {
        ids: vec![0],
        arrived: vec![Duration::ZERO],
        pixels: vec![0.0; 7], // wrong size
    });
    let r = pool.recv_timeout(Duration::from_secs(30)).expect("result");
    assert!(r.error.is_some(), "malformed job must error");

    // The same worker keeps serving good jobs afterwards.
    pool.submit(BatchJob {
        ids: vec![1],
        arrived: vec![Duration::ZERO],
        pixels: video.frame(0),
    });
    let r = pool.recv_timeout(Duration::from_secs(60)).expect("result");
    assert!(r.error.is_none());
    assert_eq!(r.ids, vec![1]);
    pool.shutdown();
}
