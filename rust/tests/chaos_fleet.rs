//! Chaos-fleet acceptance tests: the fault-injecting [`ChaosEnv`]
//! decorator driven over real simulated fleets and the scripted
//! fleet-robustness contract it forced (EXPERIMENTS.md §Chaos fleet).
//!
//! The simulated legs pin the headline claim — CORAL re-converges
//! within a bounded number of windows after *every* scheduled fault in
//! every `CHAOS_SCENARIOS` family, and an arbitrated multi-tenant box
//! recovers through the same decorator. The scripted legs pin the
//! structural contract underneath: a down or panicking member is a
//! per-member failed observation aggregated over survivors, never a
//! poisoned fleet round, and a fault-free chaos schedule is a
//! byte-identical passthrough.

mod common;

use common::StepEnv;
use coral::control::{
    drive_coral, BudgetPolicy, ChaosEnv, ChaosEvent, ChaosSchedule, Environment, FleetEnv,
    GlitchKind,
};
use coral::device::{ConfigSpace, DeviceKind, HwConfig, Measured};
use coral::experiments::scenarios::{ChaosScenario, TenantScenario, CHAOS_SCENARIOS};
use coral::optimizer::Constraints;

const SEED: u64 = 42;
/// Every scheduled event must see a re-feasible window within this many
/// windows (dropouts hold their member down for 4–6 of them, and a
/// search→hold cycle runs ~15, so the bound leaves two full re-search
/// cycles of slack).
const RECOVERY_BOUND: u64 = 45;

#[test]
fn every_chaos_family_reconverges_within_bounded_windows() {
    for s in &CHAOS_SCENARIOS {
        let done = drive_coral(s.chaos(SEED), s.constraints(), SEED, s.windows);
        assert!(
            !done.recoveries().is_empty(),
            "{}: the schedule must actually fire events",
            s.name
        );
        for r in done.recoveries() {
            let w = r.windows().unwrap_or_else(|| {
                panic!("{}: event {} at window {} never recovered", s.name, r.label, r.at_window)
            });
            assert!(
                w <= RECOVERY_BOUND,
                "{}: event {} took {w} windows to recover (bound {RECOVERY_BOUND})",
                s.name,
                r.label
            );
        }
        assert!(done.mean_recovery_windows().is_finite(), "{}", s.name);
    }
}

#[test]
fn arbitrated_multi_tenant_box_recovers_through_chaos() {
    // The combined window of an arbitrated box is the tenant mean
    // (`FleetEnv::combine` over per-tenant held windows), so the
    // decorator judges recovery against mean targets and the global
    // envelope split evenly.
    let ts = TenantScenario::by_name("nx-pair").expect("tenant scenario exists");
    let n = ts.tenants.len() as f64;
    let mean_target: f64 = ts.tenants.iter().map(|t| t.target_fps).sum::<f64>() / n;
    let cons = Constraints::dual(mean_target, ts.global_budget_mw / n);
    let schedule = ChaosSchedule::new()
        .at(1, ChaosEvent::ThermalEnable { model: ChaosScenario::thermal_model() })
        .at(3, ChaosEvent::HeatSoak { power_mw: 30_000.0, soak_s: 60.0 })
        .at(5, ChaosEvent::GlitchBurst { windows: 1, kind: GlitchKind::NonFinite });
    let arb = ts.arbiter(BudgetPolicy::DemandWeighted, SEED);
    let mut env = ChaosEnv::new(arb, schedule, cons);
    let probe = env.space().midpoint(); // ignored: each window is one round
    for _ in 0..10 {
        env.measure(probe);
    }
    assert_eq!(env.recoveries().len(), 3, "all three events fired");
    for r in env.recoveries() {
        let w = r
            .windows()
            .unwrap_or_else(|| panic!("{}: never re-reached the combined targets", r.label));
        assert!(w <= 5, "{}: {w} rounds to recover", r.label);
    }
}

/// A scripted mixed fleet: member 0 serves a constant 30 fps at 5 W,
/// member 1 a constant 60 fps at 3 W, both on the NX grid.
fn scripted_fleet(sequential: bool) -> FleetEnv {
    let a = StepEnv::constant().with_levels(30.0, 30.0).with_power(5_000.0);
    let b = StepEnv::constant().with_levels(60.0, 60.0).with_power(3_000.0);
    let members: Vec<Box<dyn Environment + Send>> = vec![Box::new(a), Box::new(b)];
    let fleet = FleetEnv::new(members);
    if sequential {
        fleet.sequential()
    } else {
        fleet
    }
}

#[test]
fn a_down_member_is_a_survivor_aggregate_not_a_failed_round() {
    for sequential in [false, true] {
        let mut fleet = scripted_fleet(sequential);
        let cfg = fleet.space().midpoint();
        let healthy = fleet.measure(cfg);
        assert_eq!(healthy.throughput_fps, 45.0);
        assert_eq!(healthy.power_mw, 4_000.0);

        fleet.set_member_down(0, true);
        assert_eq!(fleet.live_members(), 1);
        let m = fleet.measure(cfg);
        assert!(
            m.failed.is_none(),
            "sequential={sequential}: one dropped member must not mark the \
             fleet window failed: {:?}",
            m.failed
        );
        assert_eq!(m.throughput_fps, 60.0, "mean over the one survivor");
        assert_eq!(m.power_mw, 3_000.0, "mean over the one survivor");

        fleet.set_member_down(0, false);
        let back = fleet.measure(cfg);
        assert_eq!(back.throughput_fps, 45.0, "rejoin restores the full mean");
        assert_eq!(back.power_mw, 4_000.0);
    }
}

/// A member whose board has died hard: every measurement panics.
struct PanickingEnv {
    space: ConfigSpace,
}

impl Environment for PanickingEnv {
    fn measure(&mut self, _cfg: HwConfig) -> Measured {
        panic!("injected member panic");
    }

    fn space(&self) -> &ConfigSpace {
        &self.space
    }

    fn cost_s(&self) -> f64 {
        0.0
    }
}

#[test]
fn a_panicking_member_never_poisons_the_fleet_round() {
    for sequential in [false, true] {
        let healthy = StepEnv::constant().with_levels(60.0, 60.0).with_power(3_000.0);
        let dead = PanickingEnv { space: DeviceKind::XavierNx.space() };
        let members: Vec<Box<dyn Environment + Send>> =
            vec![Box::new(healthy), Box::new(dead)];
        let mut fleet = FleetEnv::new(members);
        if sequential {
            fleet = fleet.sequential();
        }
        let cfg = fleet.space().midpoint();
        for round in 0..3 {
            let m = fleet.measure(cfg);
            assert!(
                m.failed.is_none(),
                "sequential={sequential} round {round}: a panicked member job must \
                 become a dropped observation, not poison the round: {:?}",
                m.failed
            );
            assert_eq!(m.throughput_fps, 60.0, "aggregate over the survivor");
            assert_eq!(m.power_mw, 3_000.0);
        }
    }
}

#[test]
fn fault_free_chaos_schedule_is_byte_identical_to_the_undecorated_fleet() {
    let s = &CHAOS_SCENARIOS[0];
    let mut plain = s.fleet(7);
    let mut chaos = ChaosEnv::new(s.fleet(7), ChaosSchedule::new(), s.constraints());
    let space = plain.space().clone();
    let mut rng = coral::util::Rng::new(13);
    for i in 0..15 {
        let cfg = space.random(&mut rng);
        let a = plain.measure(cfg);
        let b = chaos.measure(cfg);
        assert_eq!(
            format!("{a:?}"),
            format!("{b:?}"),
            "window {i}: fault-free chaos diverged from the undecorated fleet"
        );
    }
    assert_eq!(plain.cost_s(), chaos.cost_s());
    assert!(chaos.recoveries().is_empty());
}
