//! Integration over the experiment harness: the full suite runs, writes
//! well-formed CSVs, and the regenerated numbers keep the paper's shape.

use std::path::PathBuf;

use coral::device::DeviceKind;
use coral::experiments::{dual, fig1, single, table4};
use coral::models::ModelKind;
use coral::util::csv::Csv;

fn tmp(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("coral_exp_{name}"));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

#[test]
fn fig1_csvs_written_and_parse() {
    let dir = tmp("fig1");
    fig1::run(&dir).unwrap();
    for name in ["fig1_xavier_nx.csv", "fig1_orin_nano.csv"] {
        let text = std::fs::read_to_string(dir.join(name)).unwrap();
        let csv = Csv::parse(&text).unwrap();
        assert!(csv.rows.len() > 1000, "{name}: {} rows", csv.rows.len());
        assert!(csv.col("throughput_fps").is_some());
        assert!(csv.col("power_mw").is_some());
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn table4_within_10pct_of_paper() {
    let dir = tmp("table4");
    table4::run(&dir).unwrap();
    let text = std::fs::read_to_string(dir.join("table4.csv")).unwrap();
    let csv = Csv::parse(&text).unwrap();
    let di = csv.col("delta_pct").unwrap();
    for row in &csv.rows {
        let delta: f64 = row[di].parse().unwrap();
        assert!(delta.abs() < 10.0, "row {row:?}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn single_constraint_csv_has_method_lineup() {
    let dir = tmp("single");
    single::run(&dir, 3).unwrap();
    let text = std::fs::read_to_string(dir.join("fig3_4_single.csv")).unwrap();
    let csv = Csv::parse(&text).unwrap();
    let mi = csv.col("method").unwrap();
    for m in ["oracle", "coral", "alert", "alert-online", "max-power", "default"] {
        assert!(csv.rows.iter().any(|r| r[mi] == m), "missing {m}");
    }
    // Every device appears.
    let di = csv.col("device").unwrap();
    for d in DeviceKind::ALL {
        assert!(csv.rows.iter().any(|r| r[di] == d.name()));
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn dual_csv_coral_feasible_baselines_not() {
    let dir = tmp("dual");
    dual::run_model(&dir, ModelKind::Yolo, 5).unwrap();
    let text = std::fs::read_to_string(dir.join("fig5_fig6_dual_yolo.csv")).unwrap();
    let csv = Csv::parse(&text).unwrap();
    let (mi, fi) = (csv.col("method").unwrap(), csv.col("feasible_rate").unwrap());
    let rate = |m: &str, dev: &str| -> f64 {
        let di = csv.col("device").unwrap();
        csv.rows
            .iter()
            .find(|r| r[mi] == m && r[di] == dev)
            .map(|r| r[fi].parse().unwrap())
            .unwrap()
    };
    for dev in ["xavier-nx", "orin-nano"] {
        assert_eq!(rate("oracle", dev), 1.0, "{dev}");
        assert!(rate("coral", dev) >= 0.8, "{dev} coral {}", rate("coral", dev));
        assert_eq!(rate("max-power", dev), 0.0, "{dev}");
        assert_eq!(rate("default", dev), 0.0, "{dev}");
    }
    let _ = std::fs::remove_dir_all(&dir);
}
