//! Integration regressions and properties for the measurement cache
//! (`control::cache`): hits are byte-identical, wrapping an environment
//! leaves same-seed trajectories unchanged, drift-epoch invalidation
//! never resurfaces a stale entry, and tenant epochs stay per-tenant.

use std::collections::HashMap;

use coral::control::testkit::StepEnv;
use coral::control::{
    BudgetPolicy, CachedEnv, ControlLoop, Environment, LoopEvent, LoopOutcome, Tenant,
    TenantArbiter,
};
use coral::device::{ConfigSpace, Device, DeviceKind, HwConfig, Measured};
use coral::models::ModelKind;
use coral::optimizer::{Constraints, CoralOptimizer};
use coral::util::prop;

#[test]
fn cache_hit_returns_byte_identical_measured_on_a_noisy_board() {
    // A noisy simulated board: re-measuring would draw fresh noise, so
    // any replay that is not answered from the store diverges with
    // overwhelming probability. The hit must be the stored window,
    // byte for byte, with no real window run.
    let dev = Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 42);
    let mut env = CachedEnv::new(coral::control::SimEnv::new(dev));
    let mut rng = coral::util::Rng::new(7);
    let cfgs: Vec<HwConfig> = (0..5).map(|_| env.space().random(&mut rng)).collect();
    let first: Vec<Measured> = cfgs.iter().map(|&c| env.measure(c)).collect();
    let windows_after_first = env.inner().device().windows_run();
    let second: Vec<Measured> = cfgs.iter().map(|&c| env.measure(c)).collect();
    assert_eq!(first, second, "hits must replay the stored windows exactly");
    assert_eq!(
        env.inner().device().windows_run(),
        windows_after_first,
        "no real window may back a hit"
    );
    assert!(env.stats().hits >= 5);
}

/// One search round over `env` with a fixed optimizer seed, digesting
/// everything an outcome exposes that a cache layer must not perturb.
fn drive(env: Box<dyn Environment + Send>) -> (String, LoopOutcome, bool) {
    let cons = Constraints::dual(25.0, 6000.0);
    let opt = CoralOptimizer::new(env.space().clone(), cons, 9);
    let mut cl = ControlLoop::with_budget(env, opt, cons, 10);
    let out = cl.run();
    let digest = format!(
        "{:?}|{:?}|{:?}|{:?}",
        out.best,
        out.first_feasible_iter,
        out.feasible_by_iter,
        out.trace
            .steps
            .iter()
            .map(|s| (s.config, s.throughput_fps, s.power_mw))
            .collect::<Vec<_>>()
    );
    let cache_events = cl
        .events()
        .iter()
        .any(|e| matches!(e, LoopEvent::Cache { .. }));
    (digest, out, cache_events)
}

#[test]
fn wrapping_the_env_leaves_the_same_seed_trajectory_unchanged() {
    // Deterministic surfaces (noise off / scripted constant), same
    // optimizer seed: the cached loop must walk the identical
    // trajectory — the cache's same-seed determinism contract.
    let quiet =
        || Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 5).with_noise_scale(0.0);
    let pairs: Vec<(Box<dyn Environment + Send>, Box<dyn Environment + Send>)> = vec![
        (
            Box::new(coral::control::SimEnv::new(quiet())),
            Box::new(CachedEnv::new(coral::control::SimEnv::new(quiet()))),
        ),
        (
            Box::new(StepEnv::constant()),
            Box::new(CachedEnv::new(StepEnv::constant())),
        ),
    ];
    for (plain, cached) in pairs {
        let (d_plain, out_plain, ev_plain) = drive(plain);
        let (d_cached, out_cached, ev_cached) = drive(cached);
        assert_eq!(d_plain, d_cached, "wrapping must not perturb the trajectory");
        assert!(out_plain.cache.is_none(), "plain loops report no cache stats");
        assert!(!ev_plain, "plain event logs carry no Cache events");
        let st = out_cached.cache.expect("cached loops report stats");
        assert!(ev_cached, "cached loops log Cache events");
        assert_eq!(st.epoch, 0, "no drift, no bump");
        assert_eq!(st.lookups(), st.hits + st.misses);
    }
}

/// Deterministic counter surface: every real window returns a value
/// never produced before (`windows` strictly increases), so a stale
/// cache entry is distinguishable from any fresh measurement.
struct CounterEnv {
    space: ConfigSpace,
    windows: u64,
}

impl Environment for CounterEnv {
    fn measure(&mut self, cfg: HwConfig) -> Measured {
        self.windows += 1;
        Measured {
            config: cfg,
            throughput_fps: self.windows as f64,
            power_mw: 1000.0,
            latency_ms: 1.0,
            p99_latency_ms: 1.0,
            gpu_util: 0.5,
            cpu_util: 0.5,
            mem_util: 0.5,
            accuracy: 30.0,
            failed: None,
        }
    }

    fn space(&self) -> &ConfigSpace {
        &self.space
    }

    fn cost_s(&self) -> f64 {
        self.windows as f64
    }
}

#[test]
fn property_no_pre_epoch_entry_survives_a_bump() {
    // Model-based property over random op sequences: a cached measure
    // must return exactly what a per-epoch model predicts — the stored
    // value within an epoch, a *fresh* (strictly newer) value for the
    // first lookup after any bump. 120 seeded cases.
    prop::check("post-bump lookups never see pre-epoch entries", 120, |g| {
        let space = DeviceKind::XavierNx.space();
        let mut env = CachedEnv::new(CounterEnv { space: space.clone(), windows: 0 });
        let cfgs: Vec<HwConfig> =
            (0..4).map(|_| env.space().random(&mut g.rng)).collect();
        // What the current epoch may legitimately serve per config.
        let mut model: HashMap<HwConfig, f64> = HashMap::new();
        for _ in 0..30 {
            let op = g.rng.below(10);
            if op < 6 {
                // measure: hit iff the model holds this config.
                let cfg = *g.rng.choose(&cfgs);
                let windows_before = env.inner().windows;
                let m = env.measure(cfg);
                match model.get(&cfg) {
                    Some(&v) => {
                        prop::assert_close(m.throughput_fps, v, 0.0)?;
                        prop::assert_true(
                            env.inner().windows == windows_before,
                            "a hit must not run a real window",
                        )?;
                    }
                    None => {
                        prop::assert_close(
                            m.throughput_fps,
                            (windows_before + 1) as f64,
                            0.0,
                        )?;
                        model.insert(cfg, m.throughput_fps);
                    }
                }
            } else if op < 8 {
                // measure_fresh: always a real window, entry refreshed.
                let cfg = *g.rng.choose(&cfgs);
                let windows_before = env.inner().windows;
                let m = env.measure_fresh(cfg);
                prop::assert_close(m.throughput_fps, (windows_before + 1) as f64, 0.0)?;
                model.insert(cfg, m.throughput_fps);
            } else {
                // drift bump: everything cached so far is dead.
                let epoch_before = env.epoch();
                env.bump_epoch();
                prop::assert_true(env.epoch() == epoch_before + 1, "epoch advances")?;
                prop::assert_true(
                    env.store().is_empty(),
                    "a bump prunes every entry of this surface",
                )?;
                model.clear();
            }
        }
        Ok(())
    });
}

#[test]
fn arrival_profile_is_part_of_the_cache_fingerprint() {
    // Satellite regression: a cached measurement taken under one offered
    // load must never answer a lookup under another. The environment
    // fingerprint (which keys the cache's surface identity) has to fold
    // in the arrival profile — rate, phase schedule, and seed — and the
    // no-load environment must differ from every loaded one.
    use coral::workload::ArrivalProfile;
    let dev = || Device::new(DeviceKind::XavierNx, ModelKind::Yolo, 3).with_noise_scale(0.0);
    let base = coral::control::SimEnv::new(dev());
    let profiles = [
        ArrivalProfile::steady(30.0, 1),
        ArrivalProfile::steady(60.0, 1),  // rate differs
        ArrivalProfile::steady(30.0, 2),  // seed differs
        ArrivalProfile::diurnal(30.0, 1), // phase schedule differs
        ArrivalProfile::flash_crowd(30.0, 1),
    ];
    let mut prints = vec![base.fingerprint()];
    for p in &profiles {
        prints.push(coral::control::SimEnv::new(dev()).under_load(p.clone()).fingerprint());
    }
    for i in 0..prints.len() {
        for j in (i + 1)..prints.len() {
            assert_ne!(
                prints[i], prints[j],
                "fingerprints {i} and {j} collide: two load surfaces would share a cache"
            );
        }
    }
    // Same profile, same device → same fingerprint (hits still possible).
    let a = coral::control::SimEnv::new(dev())
        .under_load(ArrivalProfile::steady(30.0, 1))
        .fingerprint();
    assert_eq!(a, prints[1], "identical load surfaces must still share entries");
}

const TENANT_NAMES: [&str; 3] = ["prop-t0", "prop-t1", "prop-t2"];

#[test]
fn property_tenant_drift_restarts_stay_per_tenant() {
    // Random cached tenant mixes where exactly one scripted tenant
    // drifts mid-run: after two arbitration rounds the drifter's epoch
    // advanced, every steady tenant still sits at epoch 0 with live
    // (hitting) entries, and the drifter's reported allocation reflects
    // the post-drift surface — never a resurrected pre-drift window.
    // 100 seeded cases.
    prop::check("tenant epochs are isolated", 100, |g| {
        let n = 2 + g.rng.below(2); // 2..=3 tenants
        let drifter = g.rng.below(n);
        let base_seed = g.rng.below(1 << 16) as u64;
        let policy = if g.rng.below(2) == 0 {
            BudgetPolicy::DemandWeighted
        } else {
            BudgetPolicy::WaterFill
        };
        let mut arb = TenantArbiter::new(6000.0 * n as f64, policy).cached(true);
        if g.rng.below(2) == 0 {
            arb = arb.sequential();
        }
        for i in 0..n {
            let env = if i == drifter {
                // Steps 30 → 15 fps somewhere between mid-search and
                // mid-hold of round 1: the hold detector must fire.
                StepEnv::new(g.rng.range_usize(5, 12) as u64)
            } else {
                StepEnv::constant()
            };
            arb.add_tenant(
                Tenant {
                    name: TENANT_NAMES[i],
                    model: ModelKind::ALL[i],
                    target_fps: 20.0,
                    weight: 1.0,
                    min_accuracy: None,
                },
                Box::new(env.with_power(2000.0)),
                base_seed + i as u64,
            );
        }
        arb.run_round();
        arb.run_round();
        let stats = arb.tenant_cache_stats();
        for (i, st) in stats.iter().enumerate() {
            let st = st.expect("cached arbiter wraps every tenant");
            if i == drifter {
                prop::assert_true(st.epoch >= 1, "the drifting tenant must bump")?;
            } else {
                prop::assert_true(
                    st.epoch == 0,
                    "a neighbour's restart must not touch this tenant's epoch",
                )?;
                prop::assert_true(
                    st.hits > 0,
                    "steady tenants keep replaying their live entries",
                )?;
            }
        }
        // Post-drift the surface serves 15 fps; a resurfaced pre-epoch
        // entry would report 30.
        let last = arb.history().last().expect("two rounds ran");
        prop::assert_close(last.tenants[drifter].chosen.throughput_fps, 15.0, 0.0)
    });
}
