//! Heterogeneous-fleet acceptance tests: one CORAL [`ControlLoop`]
//! drives a [`FleetEnv`] whose members carry *different* native
//! configuration spaces (Xavier NX + Orin Nano), through the normalized
//! rank-fraction grid (`device::NormSpace`).
//!
//! Scripted (testkit) members make the surfaces exact, so the assertions
//! pin the structural contract rather than simulator statistics:
//! every decoded per-member configuration lands on that member's native
//! grid, and same-seed parallel vs sequential trajectories are
//! byte-identical. `bench_hetero` scores the shared-vs-independent
//! comparison on the simulated boards (EXPERIMENTS.md §Heterogeneous
//! fleets).

mod common;

use common::StepEnv;
use coral::control::{ControlLoop, Environment, FleetEnv, LoopOutcome};
use coral::device::DeviceKind;
use coral::experiments::scenarios::HETERO_SCENARIOS;
use coral::optimizer::{Constraints, CoralOptimizer};

/// A scripted mixed-space fleet: the NX member serves a constant 30 fps
/// at 5 W, the Orin member a constant 60 fps at 3 W — fleet mean 45 fps
/// at 4 W, regardless of configuration.
fn scripted_mixed_fleet(sequential: bool) -> FleetEnv {
    let nx = StepEnv::constant()
        .with_space(DeviceKind::XavierNx.space())
        .with_levels(30.0, 30.0)
        .with_power(5_000.0);
    let orin = StepEnv::constant()
        .with_space(DeviceKind::OrinNano.space())
        .with_levels(60.0, 60.0)
        .with_power(3_000.0);
    let members: Vec<Box<dyn Environment + Send>> = vec![Box::new(nx), Box::new(orin)];
    let fleet = FleetEnv::new(members);
    if sequential {
        fleet.sequential()
    } else {
        fleet
    }
}

fn run_scripted(sequential: bool, seed: u64) -> (LoopOutcome, ControlLoop<FleetEnv, CoralOptimizer>) {
    let fleet = scripted_mixed_fleet(sequential);
    let cons = Constraints::dual(40.0, 4_500.0);
    let opt = CoralOptimizer::new(fleet.space().clone(), cons, seed);
    let mut cl = ControlLoop::with_budget(fleet, opt, cons, 10);
    let out = cl.run();
    (out, cl)
}

#[test]
fn coral_drives_a_mixed_space_fleet_with_on_grid_decoding() {
    let (out, cl) = run_scripted(false, 42);
    assert_eq!(out.iters, 10);
    let best = out.best.expect("scripted members always measure");
    assert!(best.feasible, "fleet mean 45 fps @ 4 W meets 40 fps / 4.5 W");
    assert_eq!(best.throughput_fps, 45.0, "mean of 30 and 60 fps members");
    assert_eq!(best.power_mw, 4_000.0, "mean of 5 W and 3 W members");
    assert_eq!(out.first_feasible_iter, Some(1), "every window is feasible");

    let fleet = cl.into_env();
    assert!(fleet.is_normalized());
    let grid = fleet.space().clone();
    assert!(grid.is_normalized());
    let ns = fleet.norm().expect("mixed fleet has an encoding");
    for step in &out.trace.steps {
        assert!(
            grid.contains(&step.config),
            "proposal off the normalized grid: {:?}",
            step.config
        );
        let natives = fleet.decoded(step.config);
        assert_eq!(natives.len(), 2);
        for (i, native) in natives.iter().enumerate() {
            assert!(
                ns.members()[i].contains(native),
                "iteration {}: member {i} decoded off its native grid ({native})",
                step.iter
            );
        }
        // NX and Orin CPU grids are disjoint value sets: the same
        // fraction always decodes to genuinely different native units.
        assert_ne!(natives[0], natives[1]);
    }
}

#[test]
fn same_seed_parallel_and_sequential_trajectories_are_byte_identical() {
    let (par, _) = run_scripted(false, 7);
    let (seq, _) = run_scripted(true, 7);
    assert_eq!(
        format!("{:?}", par.trace),
        format!("{:?}", seq.trace),
        "thread scheduling must never change a trajectory"
    );
    assert_eq!(par.iters, seq.iters);
    assert_eq!(par.cost_s, seq.cost_s);
}

#[test]
fn sim_backed_hetero_scenario_drives_end_to_end_on_grid() {
    // The real mixed simulated boards (hetero-yolo-pair): structural
    // guarantees only — every proposal on the normalized grid, every
    // decode on the member grids, determinism across runs.
    let s = HETERO_SCENARIOS[0];
    let run = |sequential: bool| {
        let fleet = if sequential { s.fleet(11).sequential() } else { s.fleet(11) };
        let cons = s.constraints();
        let opt = CoralOptimizer::new(fleet.space().clone(), cons, 11);
        let mut cl = ControlLoop::with_budget(fleet, opt, cons, 10);
        let out = cl.run();
        (out, cl.into_env())
    };
    let (out, fleet) = run(false);
    assert_eq!(out.iters, 10);
    assert!(out.best.is_some());
    let ns = fleet.norm().expect("hetero scenario fleet is normalized");
    for step in &out.trace.steps {
        for (i, native) in fleet.decoded(step.config).iter().enumerate() {
            assert!(ns.members()[i].contains(native), "member {i}");
        }
    }
    let (out_seq, _) = run(true);
    assert_eq!(
        format!("{:?}", out.trace),
        format!("{:?}", out_seq.trace),
        "sim-backed mixed fleet: parallel == sequential"
    );

    // Non-vacuity: different board seeds drive different measurement
    // noise, so the trajectories genuinely diverge.
    let other_fleet = s.fleet(12);
    let opt = CoralOptimizer::new(other_fleet.space().clone(), s.constraints(), 11);
    let mut cl = ControlLoop::with_budget(other_fleet, opt, s.constraints(), 10);
    let other = cl.run();
    assert_ne!(format!("{:?}", out.trace), format!("{:?}", other.trace));
}
