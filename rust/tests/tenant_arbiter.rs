//! Multi-tenant arbitration integration: the dual-constraint acceptance
//! run (3 tenants on one simulated board), parallel==sequential
//! determinism, and the shared-admission regression under the arbiter.
//!
//! Scripted environments come from `common` (re-exporting
//! `coral::control::testkit`) — the same definitions the unit tests use.

mod common;

use common::scripted_pair;

use coral::control::{BudgetPolicy, Environment};
use coral::coordinator::Router;
use coral::experiments::scenarios::TenantScenario;
use coral::models::ModelKind;

/// Acceptance: 3 tenants on one simulated NX, each reaching its
/// throughput target, while the box's aggregate measured power never
/// exceeds the 21 W global envelope on any round.
#[test]
fn three_tenants_meet_targets_within_global_budget() {
    let s = TenantScenario::by_name("nx-triple").expect("scenario exists");
    // Demand-weighted: every round re-searches under the same generous
    // demand split, so each round is an independent shot at
    // simultaneous feasibility (water-filling's donor-tightening is
    // exercised by the unit and property tests instead).
    let mut arb = s.arbiter(BudgetPolicy::DemandWeighted, 0xC0FFEE);
    let reports = arb.run(6).to_vec();
    assert_eq!(reports.len(), 6);

    for r in &reports {
        assert!(
            r.aggregate_power_mw <= s.global_budget_mw,
            "round {}: box drew {:.0} mW of the {:.0} mW envelope",
            r.round,
            r.aggregate_power_mw,
            s.global_budget_mw
        );
        assert_eq!(r.overshoot_mw, 0.0);
        let sum: f64 = r.tenants.iter().map(|t| t.sub_budget_mw).sum();
        assert!(
            sum <= s.global_budget_mw * (1.0 + 1e-9),
            "round {}: sub-budgets sum {sum:.0} exceed the envelope",
            r.round
        );
    }

    // Every tenant reaches its target (a feasible held window really
    // means target met under its sub-budget)...
    for (i, t) in s.tenants.iter().enumerate() {
        let hit = reports.iter().any(|r| {
            let tr = &r.tenants[i];
            assert_eq!(tr.name, t.name, "tenant order is stable");
            tr.feasible && tr.chosen.throughput_fps >= t.target_fps
        });
        assert!(hit, "{} never reached {} fps under its sub-budget", t.name, t.target_fps);
    }
    // ...and some round satisfies all three at once (water-filling keeps
    // shifting slack toward whoever still misses).
    assert!(
        reports.iter().any(|r| r.tenants.iter().all(|t| t.feasible)),
        "no round had every tenant simultaneously on target: {reports:?}"
    );
}

/// Same-seed runs are identical trajectories, parallel and sequential —
/// the FleetRunner scheduling must never leak into the numbers.
#[test]
fn same_seed_parallel_and_sequential_trajectories_identical() {
    let s = TenantScenario::by_name("nx-triple").expect("scenario exists");
    let mut par = s.arbiter(BudgetPolicy::WaterFill, 7);
    let mut seq = s.arbiter(BudgetPolicy::WaterFill, 7).sequential();
    par.run(3);
    seq.run(3);
    assert_eq!(
        format!("{:?}", par.history()),
        format!("{:?}", seq.history()),
        "parallel tenant rounds must be byte-identical to sequential"
    );

    // Re-running the parallel path reproduces itself; a different seed
    // diverges (the determinism is seeded, not degenerate).
    let mut again = s.arbiter(BudgetPolicy::WaterFill, 7);
    again.run(3);
    assert_eq!(format!("{:?}", par.history()), format!("{:?}", again.history()));
    let mut other = s.arbiter(BudgetPolicy::WaterFill, 8);
    other.run(3);
    assert_ne!(format!("{:?}", par.history()), format!("{:?}", other.history()));
}

/// The arbiter presents as an `Environment`: one `measure` is one
/// arbitration round reporting the fleet-combined held window.
#[test]
fn arbiter_environment_rounds_accumulate_cost() {
    let mut arb = scripted_pair(9_000.0, 3_000.0);
    let probe = arb.space().midpoint();
    let m1 = arb.measure(probe);
    let c1 = arb.cost_s();
    let m2 = arb.measure(probe);
    assert_eq!(arb.rounds(), 2);
    assert!(m1.power_mw > 0.0 && m2.power_mw > 0.0);
    assert!(arb.cost_s() > c1, "each round consumes measurement windows");
}

/// Regression (shared admission under the arbiter): `Router::rejected`
/// is one shared counter across tenants — one tenant's burst rejections
/// must neither reset nor double-count when another tenant's round
/// reconfigures concurrency through the same router.
#[test]
fn router_rejected_counter_survives_tenant_reconfigurations() {
    let mut arb = scripted_pair(9_000.0, 3_000.0);

    let mut router: Router<common::QueueServer> = Router::new();
    router.admission_limit = 2;
    router.register(ModelKind::Yolo, common::QueueServer::default());
    router.register(ModelKind::Frcnn, common::QueueServer::default());

    // Tenant A's burst: 2 admitted, 3 shed by admission control.
    for id in 0..5 {
        let _ = router.route(ModelKind::Yolo, id, Vec::new()).unwrap();
    }
    assert_eq!(router.rejected(), 3);

    // A round reconfigures both tenants' stacks through the shared
    // front door; the counter must survive untouched.
    arb.run_round();
    arb.apply_to_router(&mut router);
    let b = router.server(ModelKind::Frcnn).expect("registered");
    assert_eq!(b.reconfigs, 1, "round pushed tenant B's arbitrated level");
    assert!(b.concurrency >= 1);
    assert_eq!(
        router.rejected(),
        3,
        "reconfiguration must not reset the shared admission counter"
    );

    // Tenant B's own burst accumulates into the same counter.
    for id in 0..4 {
        let _ = router.route(ModelKind::Frcnn, 100 + id, Vec::new()).unwrap();
    }
    assert_eq!(router.rejected(), 5);

    // Another round + reconfig: still 5 — not reset, not double-counted.
    arb.run_round();
    arb.apply_to_router(&mut router);
    assert_eq!(router.rejected(), 5);
    assert_eq!(router.server(ModelKind::Frcnn).unwrap().reconfigs, 2);

    // Draining reopens admission without retroactive counting.
    while !router.tick().is_empty() {}
    assert!(router.route(ModelKind::Yolo, 50, Vec::new()).unwrap());
    assert_eq!(router.rejected(), 5);
}
