//! Shared scripted-environment harness for integration tests.
//!
//! Re-exports `coral::control::testkit` — the crate's scripted
//! environments and queue-shaped servers, compiled for test targets via
//! the self dev-dependency's `testkit` feature — so integration tests
//! drive the very same definitions the unit tests do: no scripted
//! environment is defined twice anywhere in the repo.

#![allow(dead_code)] // each test binary uses only the slice it needs

pub use coral::control::testkit::{QueueServer, StepEnv};

use coral::control::{BudgetPolicy, Tenant, TenantArbiter};
use coral::models::ModelKind;

/// Two scripted tenants (YOLO + FRCNN keys, constant 30-fps surfaces at
/// `power_mw` each) on a shared `global_budget_mw` envelope — the
/// minimal arbiter most integration tests want.
pub fn scripted_pair(global_budget_mw: f64, power_mw: f64) -> TenantArbiter {
    let mut arb = TenantArbiter::new(global_budget_mw, BudgetPolicy::DemandWeighted)
        .budget_iters(3)
        .hold_windows(0);
    arb.add_tenant(
        Tenant { name: "cam", model: ModelKind::Yolo, target_fps: 20.0, weight: 1.0, min_accuracy: None },
        Box::new(StepEnv::constant().with_power(power_mw)),
        1,
    );
    arb.add_tenant(
        Tenant { name: "lidar", model: ModelKind::Frcnn, target_fps: 20.0, weight: 1.0, min_accuracy: None },
        Box::new(StepEnv::constant().with_power(power_mw)),
        2,
    );
    arb
}
