//! Cross-module integration: optimizers × device simulator across the
//! full scenario matrix (no PJRT needed).

use coral::control::{ControlLoop, SimEnv};
use coral::device::{Device, DeviceKind};
use coral::experiments::runner::{run_method, MethodKind, ITER_BUDGET};
use coral::experiments::scenarios::DUAL_SCENARIOS;
use coral::models::ModelKind;
use coral::optimizer::{Constraints, CoralOptimizer, Optimizer};

#[test]
fn coral_feasible_on_every_dual_scenario() {
    // The paper's central claim (§IV-B, §IV-C): CORAL finds valid
    // configurations on both devices and all three model sizes.
    for s in DUAL_SCENARIOS {
        let cons = Constraints::dual(s.target_fps, s.budget_mw);
        let mut hits = 0;
        let runs = 10;
        for seed in 0..runs {
            let o = run_method(MethodKind::Coral, s.device, s.model, cons, 0x1731 + seed);
            if o.feasible {
                hits += 1;
            }
        }
        assert!(
            hits * 10 >= runs * 7,
            "{}/{}: CORAL feasible only {hits}/{runs}",
            s.device,
            s.model
        );
    }
}

#[test]
fn coral_beats_every_online_baseline_on_feasibility() {
    let mut coral_total = 0;
    let mut online_best = 0;
    for s in DUAL_SCENARIOS {
        let cons = Constraints::dual(s.target_fps, s.budget_mw);
        for seed in 0..6 {
            if run_method(MethodKind::Coral, s.device, s.model, cons, seed).feasible {
                coral_total += 1;
            }
            let alert_online =
                run_method(MethodKind::AlertOnline, s.device, s.model, cons, seed).feasible;
            let random =
                run_method(MethodKind::Random, s.device, s.model, cons, seed).feasible;
            if alert_online || random {
                online_best += 1;
            }
        }
    }
    assert!(
        coral_total > online_best,
        "coral {coral_total} vs best-of-online-baselines {online_best}"
    );
}

#[test]
fn search_cost_orders_of_magnitude_below_profiling() {
    // §I: "orders of magnitude faster than profiling-based alternatives".
    let s = DUAL_SCENARIOS[0];
    let cons = coral::experiments::scenarios::dual_constraints(s.device, s.model);
    let coral = run_method(MethodKind::Coral, s.device, s.model, cons, 1);
    let alert = run_method(MethodKind::Alert, s.device, s.model, cons, 1);
    assert_eq!(coral.offline_windows, 0);
    assert!(alert.offline_windows as f64 / coral.online_windows as f64 > 100.0);
}

#[test]
fn convergence_within_budget_is_stable_across_models() {
    // ≤10 iterations must be enough (paper §III-B).
    for model in ModelKind::ALL {
        let cons =
            coral::experiments::scenarios::dual_constraints(DeviceKind::OrinNano, model);
        let dev = Device::new(DeviceKind::OrinNano, model, 77);
        let opt = CoralOptimizer::new(dev.space().clone(), cons, 77);
        let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, ITER_BUDGET);
        assert!(cl.run().best.is_some(), "{model}");
    }
}

#[test]
fn single_target_all_models_track_oracle() {
    // §IV-B reports 96-100 % for YOLO; heavier models must stay close too.
    for model in ModelKind::ALL {
        for device in DeviceKind::ALL {
            let probe = Device::new(device, model, 0);
            let oracle_fps = coral::device::failure::valid_configs(device, model)
                .iter()
                .map(|c| probe.true_point(c).0.throughput_fps)
                .fold(0.0f64, f64::max);
            let mut ratios = Vec::new();
            for seed in 0..6 {
                let o = run_method(
                    MethodKind::Coral,
                    device,
                    model,
                    Constraints::max_throughput(),
                    0xAB + seed,
                );
                ratios.push(o.throughput_fps / oracle_fps);
            }
            let mean = ratios.iter().sum::<f64>() / ratios.len() as f64;
            assert!(
                mean > 0.9,
                "{device}/{model}: single-target mean ratio {mean:.3}"
            );
        }
    }
}

#[test]
fn prohibited_list_shrinks_wasted_iterations() {
    // Re-proposing infeasible configs would waste the tiny budget; the
    // PS must keep all 10 proposals distinct in the dual scenario.
    let s = DUAL_SCENARIOS[4]; // NX / RetinaNet — most failures
    let cons = Constraints::dual(s.target_fps, s.budget_mw);
    let mut dev = Device::new(s.device, s.model, 5);
    let mut opt = CoralOptimizer::new(dev.space().clone(), cons, 5);
    let mut seen = std::collections::HashSet::new();
    for _ in 0..ITER_BUDGET {
        let cfg = opt.propose();
        assert!(seen.insert(cfg), "proposal repeated: {cfg}");
        let m = dev.run(cfg);
        opt.observe(cfg, m.throughput_fps, m.power_mw, m.p99_latency_ms, m.accuracy);
    }
}
