//! Integration tests for the persistent [`FleetPool`]: the teardown
//! contract (mirroring the coordinator `WorkerPool` Drop regression
//! test) and the pool-reuse determinism property — one pool reused
//! across many map / measure / sweep calls stays byte-identical to
//! fresh sequential runs, interleaved with cached sweeps.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use coral::control::{
    fleet_sweep, fleet_sweep_cached, CacheStore, Environment, FleetEnv, FleetPool, FleetRunner,
};
use coral::device::DeviceKind;
use coral::experiments::scenarios::DUAL_SCENARIOS;
use coral::models::ModelKind;
use coral::util::prop;

/// The PR-3 coordinator `WorkerPool` Drop contract, restated for the
/// fleet pool: dropping a pool with batches still queued must (a) let
/// outstanding tickets finish their batches on the joining thread and
/// (b) release every worker thread — close + wake, never join, workers
/// exit on their own once the remaining work is drained.
#[test]
fn dropping_pool_with_queued_jobs_releases_workers() {
    let pool = FleetPool::new(2);
    let watcher = pool.watcher();
    assert_eq!(watcher.alive_workers(), 2, "both workers start alive");

    // Enough slow jobs that batches are still queued at drop time.
    let ran = Arc::new(AtomicUsize::new(0));
    let tickets: Vec<_> = (0..3)
        .map(|_| {
            let ran = Arc::clone(&ran);
            pool.submit(16, move |_| {
                std::thread::sleep(Duration::from_micros(200));
                ran.fetch_add(1, Ordering::Relaxed);
            })
        })
        .collect();
    drop(pool);

    // Tickets outlive the pool: the joiner claims whatever the workers
    // abandoned, so every job still runs exactly once.
    for t in tickets {
        t.join();
    }
    assert_eq!(ran.load(Ordering::Relaxed), 3 * 16, "every queued job ran exactly once");

    // Workers observe the closed injector and exit on their own.
    let deadline = Instant::now() + Duration::from_secs(10);
    while watcher.alive_workers() != 0 {
        assert!(Instant::now() < deadline, "workers failed to exit after pool drop");
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(watcher.spawned_threads(), 2, "teardown never respawns threads");
}

/// One persistent pool, reused across hundreds of heterogeneous calls —
/// runner maps, twin fleet-member fan-outs, and (interleaved) cached and
/// uncached sweeps — must stay byte-identical to fresh sequential runs
/// the whole way through. This is the pool determinism contract under
/// realistic mixed traffic rather than one call shape at a time.
#[test]
fn pool_reuse_is_byte_identical_to_fresh_sequential_runs() {
    let runner = FleetRunner::new(3);
    let store = CacheStore::new();
    let seq_store = CacheStore::new();
    let kinds = [DeviceKind::XavierNx, DeviceKind::OrinNano, DeviceKind::OrinNano];
    let mut par = FleetEnv::mixed(&kinds, ModelKind::Yolo, 0xBEE5).with_workers(2);
    let mut seq = FleetEnv::mixed(&kinds, ModelKind::Yolo, 0xBEE5).sequential();
    let mut case = 0u64;
    prop::check("pool reuse vs fresh sequential", 100, |g| {
        case += 1;
        // (a) runner map through the shared pool vs inline sequential.
        let salt = g.rng.next_u64();
        let jobs: Vec<u64> = (0..g.rng.range_usize(1, 24) as u64).collect();
        let expect: Vec<u64> = jobs.iter().map(|j| j.wrapping_mul(salt) ^ j).collect();
        let got = runner.map(jobs, move |j| j.wrapping_mul(salt) ^ j);
        prop::assert_true(got == expect, "shared-pool map diverged from sequential")?;

        // (b) twin fleets, same proposal: the pool-parallel member
        // fan-out (and its hierarchical combine) vs the sequential twin.
        let cfg = par.space().random(&mut g.rng);
        let a = par.measure(cfg);
        let b = seq.measure(cfg);
        prop::assert_true(
            format!("{a:?}") == format!("{b:?}"),
            "fleet measure diverged from sequential twin",
        )?;

        // (c) interleaved sweeps through the same shared runner: cached
        // sweeps share one store per side, so replay passes stay
        // comparable; uncached sweeps are schedule-independent outright.
        if case % 20 == 0 {
            let scenarios = &DUAL_SCENARIOS[..1];
            let p = fleet_sweep_cached(scenarios, 2, &runner, &store);
            let s = fleet_sweep_cached(scenarios, 2, &FleetRunner::new(1), &seq_store);
            prop::assert_true(
                format!("{p:?}") == format!("{s:?}"),
                "cached sweep through the shared pool diverged",
            )?;
        }
        if case % 25 == 0 {
            let scenarios = &DUAL_SCENARIOS[..1];
            let p = fleet_sweep(scenarios, 2, &runner);
            let s = fleet_sweep(scenarios, 2, &FleetRunner::new(1));
            prop::assert_true(
                format!("{p:?}") == format!("{s:?}"),
                "uncached sweep through the shared pool diverged",
            )?;
        }
        Ok(())
    });
    // The whole run reused exactly two pools: the runner's and the
    // parallel fleet's. Zero spawns beyond their construction.
    assert_eq!(runner.spawned_threads(), 3, "runner pool built once, reused throughout");
    assert_eq!(par.spawned_threads(), 2, "fleet pool built once, reused throughout");
    assert_eq!(seq.spawned_threads(), 0, "sequential twin never builds a pool");
    assert!(!store.is_empty(), "interleaved cached sweeps populated the store");
}
