//! Event-driven coordinator regression tests — PJRT-free via stub
//! [`InferenceEngine`]s (the point of the engine seam: every pump,
//! backlog, and drain behavior is testable without AOT artifacts).
//!
//! Each satellite bugfix of the event-driven-pump PR pins its named
//! regression here:
//! * `backlog_counts_exact_inflight_requests_for_partial_batches`
//! * `drain_reconciles_against_shutdown_restoring_backpressure_budget`
//! * `zero_wall_window_reports_finite_throughput`
//! * `pump_iterations_bounded_by_completions_not_wall_time`
//!
//! (The per-window throughput-span regression is pure metrics logic and
//! lives in `coordinator::metrics::tests::reset_distributions_resets_completion_span`.)
//!
//! Note: the panic-injection tests intentionally kill worker threads,
//! so `cargo test` output may include their (expected) panic traces.

use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use coral::control::{ControlLoop, LiveEnv};
use coral::coordinator::{BatcherConfig, InferenceEngine, Server, ServerConfig, WorkerPool};
use coral::device::{Device, DeviceKind};
use coral::models::ModelKind;
use coral::optimizer::{Constraints, CoralOptimizer};
use coral::runtime::Detections;
use coral::workload::VideoSource;

const SIDE: usize = 4;

fn det() -> Detections {
    Detections { boxes: Vec::new(), scores: Vec::new() }
}

fn cfg(concurrency: usize, max_batch: usize, wait_ms: u64) -> ServerConfig {
    ServerConfig {
        concurrency,
        batcher: BatcherConfig {
            max_batch,
            max_wait: Duration::from_millis(wait_ms),
        },
    }
}

fn video() -> VideoSource {
    VideoSource::new(SIDE, 30, 7)
}

/// Completes batches immediately (a "trivially fast runtime").
struct InstantEngine;

impl InferenceEngine for InstantEngine {
    fn infer(&self, _pixels: &[f32], n: usize) -> anyhow::Result<Vec<Detections>> {
        Ok(vec![det(); n])
    }

    fn input_side(&self) -> usize {
        SIDE
    }
}

/// Simulates real compute: each batch takes a fixed wall-clock time.
struct SlowEngine(Duration);

impl InferenceEngine for SlowEngine {
    fn infer(&self, _pixels: &[f32], n: usize) -> anyhow::Result<Vec<Detections>> {
        std::thread::sleep(self.0);
        Ok(vec![det(); n])
    }

    fn input_side(&self) -> usize {
        SIDE
    }
}

/// Blocks every batch until the gate opens (holds work in flight so
/// tests can observe in-flight accounting deterministically).
struct GateEngine {
    gate: Arc<(Mutex<bool>, Condvar)>,
}

impl GateEngine {
    fn new() -> (Arc<(Mutex<bool>, Condvar)>, GateEngine) {
        let gate = Arc::new((Mutex::new(false), Condvar::new()));
        (Arc::clone(&gate), GateEngine { gate })
    }
}

fn open_gate(gate: &Arc<(Mutex<bool>, Condvar)>) {
    let (m, cv) = &**gate;
    *m.lock().unwrap() = true;
    cv.notify_all();
}

impl InferenceEngine for GateEngine {
    fn infer(&self, _pixels: &[f32], n: usize) -> anyhow::Result<Vec<Detections>> {
        let (m, cv) = &*self.gate;
        let mut open = m.lock().unwrap();
        while !*open {
            open = cv.wait(open).unwrap();
        }
        Ok(vec![det(); n])
    }

    fn input_side(&self) -> usize {
        SIDE
    }
}

/// Panics on the first `panics_left` batches (each panic kills its
/// worker thread), then serves normally — the injected-fault engine for
/// dead-pool and drain-reconciliation paths.
struct FlakyEngine {
    panics_left: AtomicUsize,
}

impl FlakyEngine {
    fn new(panics: usize) -> FlakyEngine {
        FlakyEngine { panics_left: AtomicUsize::new(panics) }
    }
}

impl InferenceEngine for FlakyEngine {
    fn infer(&self, _pixels: &[f32], n: usize) -> anyhow::Result<Vec<Detections>> {
        loop {
            let left = self.panics_left.load(Ordering::SeqCst);
            if left == 0 {
                return Ok(vec![det(); n]);
            }
            if self
                .panics_left
                .compare_exchange(left, left - 1, Ordering::SeqCst, Ordering::SeqCst)
                .is_ok()
            {
                panic!("injected worker failure");
            }
        }
    }

    fn input_side(&self) -> usize {
        SIDE
    }
}

#[test]
fn backlog_counts_exact_inflight_requests_for_partial_batches() {
    // Regression: backlog() used to charge every in-flight batch at
    // max_batch, so a deadline-released partial batch (2 requests,
    // max_batch 4) inflated the admission-control signal to 4.
    let (gate, engine) = GateEngine::new();
    let mut server = Server::with_engine(Arc::new(engine), cfg(1, 4, 0));
    let mut v = video();
    server.submit(0, v.next_frame());
    server.submit(1, v.next_frame());
    // max_wait = 0: the partial batch of 2 releases on the first tick
    // and parks inside the gated engine.
    assert!(server.tick().is_empty());
    assert_eq!(server.inflight_batches(), 1);
    assert_eq!(server.inflight_requests(), 2);
    assert_eq!(
        server.backlog(),
        2,
        "partial batch in flight must count its real 2 requests, not max_batch = 4"
    );
    open_gate(&gate);
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut done = Vec::new();
    while done.len() < 2 && Instant::now() < deadline {
        done.extend(server.tick());
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(done.len(), 2, "gated batch completes once released");
    assert_eq!(server.backlog(), 0);
    assert_eq!(server.inflight_requests(), 0);
    server.shutdown();
}

#[test]
fn set_concurrency_drains_via_completion_signal() {
    // The drain must block on the completion condvar (waking the moment
    // the in-flight batch lands), not spin or eat a fixed 30 s timeout.
    let (gate, engine) = GateEngine::new();
    let mut server = Server::with_engine(Arc::new(engine), cfg(1, 4, 0));
    let mut v = video();
    for id in 0..3 {
        server.submit(id, v.next_frame());
    }
    assert!(server.tick().is_empty());
    assert_eq!(server.inflight_batches(), 1);
    let opener = {
        let gate = Arc::clone(&gate);
        std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(50));
            open_gate(&gate);
        })
    };
    let t0 = Instant::now();
    server.set_concurrency(2);
    let drained_in = t0.elapsed();
    opener.join().unwrap();
    assert_eq!(server.concurrency(), 2);
    assert_eq!(server.inflight_batches(), 0, "drain absorbed the gated batch");
    assert_eq!(server.metrics().completed(), 3, "no request lost in the swap");
    assert!(
        drained_in < Duration::from_secs(10),
        "event-driven drain must return promptly after the completion, took {drained_in:?}"
    );
    server.shutdown();
}

#[test]
fn drain_reconciles_against_shutdown_restoring_backpressure_budget() {
    // Regression: a worker that died holding a batch (and a job no
    // worker ever picked up) used to leave `inflight_batches` pinned
    // above zero after a drain timeout, permanently shrinking tick()'s
    // pool.size() * 2 backpressure budget. The counters must reconcile
    // against what the old pool's shutdown() actually returned.
    let engine = Arc::new(FlakyEngine::new(1));
    let mut server = Server::with_engine(engine, cfg(1, 1, 0));
    let mut v = video();
    server.submit(0, v.next_frame());
    server.submit(1, v.next_frame());
    // Budget c*2 = 2: both single-request batches dispatch. The only
    // worker panics on the first; the second is orphaned with no worker
    // left to run it.
    assert!(server.tick().is_empty());
    server.set_concurrency(2);
    assert_eq!(
        server.inflight_batches(),
        0,
        "backpressure budget must be fully restored after the swap"
    );
    assert_eq!(server.inflight_requests(), 0);
    assert_eq!(server.backlog(), 0);
    assert_eq!(
        server.metrics().failed(),
        2,
        "both lost requests surfaced as failed batches, none silently lost"
    );
    // The restored budget serves real traffic again (panic budget spent).
    let report = server.run_closed_loop(&mut v, 6, 4).unwrap();
    assert_eq!(report.requests, 6);
    assert_eq!(report.failed, 0);
    assert_eq!(server.shutdown(), 6);
}

#[test]
fn zero_wall_window_reports_finite_throughput() {
    // Regression: a trivially fast runtime produced wall ~ 0 and
    // `completed / 0.0` fed inf into the telemetry window and from
    // there into dCor. The report must be NaN/inf-free, always.
    let mut server = Server::with_engine(Arc::new(InstantEngine), cfg(2, 4, 0));
    let mut v = video();
    let report = server.run_closed_loop(&mut v, 16, 16).unwrap();
    assert_eq!(report.requests, 16);
    assert!(
        report.throughput_fps.is_finite(),
        "zero-wall window must clamp, got {}",
        report.throughput_fps
    );
    assert!(report.throughput_fps >= 0.0);
    server.shutdown();
}

#[test]
fn dead_worker_surfaces_failed_batches_instead_of_submit_panic() {
    // A fully dead pool (every worker panicked) must keep terminating
    // traffic as failed batches — submit() used to panic with "workers
    // gone" and wedge the closed loop.
    let engine = Arc::new(FlakyEngine::new(2));
    let mut server = Server::with_engine(engine, cfg(2, 2, 0));
    let mut v = video();
    let r1 = server.run_closed_loop(&mut v, 4, 4).unwrap();
    assert_eq!(r1.failed, 4, "both panicked batches counted failed");
    assert_eq!(r1.requests, 0);
    // Pool is now dead; further traffic fails cleanly instead of
    // panicking or hanging.
    let r2 = server.run_closed_loop(&mut v, 3, 2).unwrap();
    assert_eq!(r2.failed, 3);
    assert_eq!(r2.requests, 0);
    assert_eq!(server.metrics().failed(), 7);
    // Reapplying the *same* concurrency level must rebuild the dead
    // pool (the old early-return kept it dead forever); the panic
    // budget is spent, so the healed server serves for real.
    server.set_concurrency(2);
    let r3 = server.run_closed_loop(&mut v, 5, 4).unwrap();
    assert_eq!(r3.requests, 5, "healed pool serves again");
    assert_eq!(r3.failed, 0);
    assert_eq!(server.shutdown(), 5);
}

#[test]
fn pump_iterations_bounded_by_completions_not_wall_time() {
    // The no-busy-wait assertion: every pump wake is a completion, a
    // batcher deadline fire, or a pool death — so the iteration count
    // is bounded by serving events, independent of how long the batches
    // take. The old 200 µs-sleep pump iterated ~ wall / 200 µs times.
    let mut server = Server::with_engine(
        Arc::new(SlowEngine(Duration::from_millis(10))),
        cfg(2, 4, 2),
    );
    let mut v = video();
    let total: u64 = 24;
    let report = server.run_closed_loop(&mut v, total, 4).unwrap();
    assert_eq!(report.requests, total);
    let event_bound = 2 * total + report.deadline_fires + 8;
    assert!(
        report.pump_iterations <= event_bound,
        "pump iterated {} times, exceeding the event bound {} ({} deadline fires)",
        report.pump_iterations,
        event_bound,
        report.deadline_fires
    );
    let polling_iterations = (report.wall_s / 200e-6) as u64;
    assert!(
        report.pump_iterations < polling_iterations,
        "event-driven pump ({} iters) must undercut the 200 µs polling pump ({} iters over {:.3} s)",
        report.pump_iterations,
        polling_iterations,
        report.wall_s
    );
    server.shutdown();
}

#[test]
fn dropping_pool_without_shutdown_releases_workers() {
    // Regression: the mpsc pool woke workers when the Sender dropped;
    // the condvar pool must do the same from Drop, or a pool dropped
    // without `shutdown()` (panicking test, detached hung pool) leaks
    // every parked worker thread — each pinning the engine Arc.
    let engine = Arc::new(InstantEngine);
    let dyn_engine: Arc<dyn InferenceEngine> = engine.clone();
    let pool = WorkerPool::new(dyn_engine, 2);
    assert_eq!(pool.alive(), 2);
    drop(pool);
    // Released workers exit and drop their engine handles; only the
    // test's own Arc remains.
    let deadline = Instant::now() + Duration::from_secs(10);
    while Arc::strong_count(&engine) > 1 && Instant::now() < deadline {
        std::thread::sleep(Duration::from_millis(1));
    }
    assert_eq!(
        Arc::strong_count(&engine),
        1,
        "workers must exit when the pool is dropped without shutdown()"
    );
}

#[test]
fn open_loop_generous_deadline_scores_every_request_a_hit() {
    use coral::workload::OpenLoopGen;
    let mut server = Server::with_engine(Arc::new(InstantEngine), cfg(2, 4, 1));
    let mut v = video();
    let mut gen = OpenLoopGen::new(2000.0, 30, 7);
    let total: u64 = 20;
    let report = server.run_open_loop(&mut v, &mut gen, total, 10_000.0).unwrap();
    assert_eq!(report.requests, total);
    assert_eq!(report.failed, 0);
    assert_eq!(report.deadline_hits, total, "instant engine beats a 10 s deadline");
    assert_eq!(report.deadline_misses, 0);
    assert!(report.throughput_fps.is_finite());
    // Closed-loop runs carry no deadlines: both counters stay zero.
    let closed = server.run_closed_loop(&mut v, 4, 4).unwrap();
    assert_eq!((closed.deadline_hits, closed.deadline_misses), (0, 0));
    server.shutdown();
}

#[test]
fn open_loop_overload_scores_misses_for_late_requests() {
    use coral::workload::OpenLoopGen;
    // Service takes 10 ms per single-request batch on one worker
    // (μ = 100/s); arrivals at 1000/s swamp it and the deadline (5 ms)
    // is below even the bare execution time — every request misses.
    let mut server = Server::with_engine(
        Arc::new(SlowEngine(Duration::from_millis(10))),
        cfg(1, 1, 0),
    );
    let mut v = video();
    let mut gen = OpenLoopGen::new(1000.0, 30, 3);
    let total: u64 = 12;
    let report = server.run_open_loop(&mut v, &mut gen, total, 5.0).unwrap();
    assert_eq!(report.requests + report.failed, total, "every request terminates");
    assert_eq!(report.deadline_hits, 0, "10 ms execution can never beat 5 ms");
    assert_eq!(report.deadline_misses, total);
    assert!(
        report.latency_p99_ms >= report.latency_p50_ms,
        "queueing under overload stretches the tail"
    );
    server.shutdown();
}

fn sim_backed_trajectory(seed: u64) -> Vec<(f64, f64)> {
    let env = LiveEnv::sim_backed(Device::new(DeviceKind::XavierNx, ModelKind::Yolo, seed));
    let cons = Constraints::dual(30.0, 6500.0);
    let opt = CoralOptimizer::new(env.device().space().clone(), cons, seed);
    let mut cl = ControlLoop::with_budget(env, opt, cons, 10);
    let out = cl.run();
    assert_eq!(cl.env().pump_iterations(), 0, "sim-backed windows never touch the pump");
    out.trace
        .steps
        .iter()
        .map(|s| (s.throughput_fps, s.power_mw))
        .collect()
}

#[test]
fn control_loop_sim_backed_trajectories_unchanged_by_pump() {
    // The event-driven pump must not perturb sim-backed measurement:
    // same-seed ControlLoop trajectories stay deterministic (and the
    // sim-backed window math itself is asserted identical to the plain
    // device path in control::env::tests).
    assert_eq!(sim_backed_trajectory(5), sim_backed_trajectory(5));
    assert_ne!(
        sim_backed_trajectory(5),
        sim_backed_trajectory(6),
        "seeds still drive distinct measurement noise"
    );
}
