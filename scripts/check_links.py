#!/usr/bin/env python3
"""Fail on dangling relative links in the repo's markdown documentation.

Checks every ``[text](target)`` link in the root-level markdown files
(README / ARCHITECTURE / EXPERIMENTS / ROADMAP / ...):

* relative file targets must exist (directories count, for links like
  ``examples/``);
* ``#anchor`` fragments — standalone or on a markdown target — must
  match a heading in the target file, using GitHub's slug rules
  (lowercase, punctuation stripped, spaces to dashes);
* absolute URLs (http/https) are skipped: the check must work offline;
* absolute *filesystem* paths (``/root/...``, ``/home/...``, ...) are
  rejected anywhere in a root markdown file — they describe one
  author's machine, not the repository — except in fenced code blocks
  and in ISSUE.md (a driver-managed work order that legitimately
  quotes container paths).

Usage: python3 scripts/check_links.py  (from anywhere; repo-root aware)
"""

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$", re.MULTILINE)
CODE_FENCE_RE = re.compile(r"```.*?```", re.DOTALL)
# Machine-local absolute paths that must never appear in committed docs.
ABS_PATH_RE = re.compile(r"(?<![\w.])/(?:root|home|opt|tmp|usr|var|etc)/[\w./-]+")
# Driver-managed work order; quotes container paths by design.
ABS_PATH_EXEMPT = {"ISSUE.md"}


def slugify(heading: str) -> str:
    """GitHub-style anchor slug for a markdown heading."""
    heading = re.sub(r"[`*_~]", "", heading.strip()).lower()
    heading = re.sub(r"[^\w\- ]", "", heading, flags=re.UNICODE)
    return heading.replace(" ", "-")


def anchors_of(md_path: Path) -> set:
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    return {slugify(h) for h in HEADING_RE.findall(text)}


def check_file(md_path: Path) -> list:
    errors = []
    text = CODE_FENCE_RE.sub("", md_path.read_text(encoding="utf-8"))
    if md_path.name not in ABS_PATH_EXEMPT and md_path.parent == REPO:
        for hit in ABS_PATH_RE.findall(text):
            errors.append(
                f"{md_path.relative_to(REPO)}: absolute filesystem path "
                f"'{hit}' (use a repo-relative path or name the thing instead)"
            )
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:")):
            continue
        path_part, _, fragment = target.partition("#")
        if path_part:
            dest = (md_path.parent / path_part).resolve()
            if not dest.exists():
                errors.append(f"{md_path.relative_to(REPO)}: dangling link '{target}'")
                continue
        else:
            dest = md_path
        if fragment:
            if dest.is_dir() or dest.suffix.lower() != ".md":
                continue  # anchors into non-markdown targets: out of scope
            if slugify(fragment) not in anchors_of(dest):
                errors.append(
                    f"{md_path.relative_to(REPO)}: missing anchor '#{fragment}' "
                    f"in {dest.relative_to(REPO)}"
                )
    return errors


def main() -> int:
    md_files = sorted(REPO.glob("*.md")) + sorted(REPO.glob("vendor/*.md"))
    if not md_files:
        print("check_links: no markdown files found", file=sys.stderr)
        return 1
    errors = []
    for md in md_files:
        errors.extend(check_file(md))
    if errors:
        print("\n".join(errors), file=sys.stderr)
        print(f"check_links: {len(errors)} dangling link(s)", file=sys.stderr)
        return 1
    print(f"check_links: {len(md_files)} files OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
