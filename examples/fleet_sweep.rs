//! Fleet sweep: CORAL across the whole fleet — every (device, model)
//! dual-constraint scenario × many seeds — reporting convergence
//! statistics (feasibility rate, iterations-to-first-feasible, search
//! cost), plus a multi-model Router demo when artifacts are present.
//!
//! The sweep runs thread-parallel through `control::FleetRunner`; per-job
//! deterministic seeding makes the numbers byte-identical to a
//! sequential run, just wall-clock faster.
//!
//! ```sh
//! cargo run --release --example fleet_sweep
//! ```

use std::time::Duration;

use coral::control::{fleet_sweep, fleet_sweep_cached, CacheStore, FleetRunner};
use coral::coordinator::{BatcherConfig, Router, Server, ServerConfig};
use coral::experiments::scenarios::DUAL_SCENARIOS;
use coral::models::{artifacts_dir, Manifest, ModelKind};
use coral::runtime::PjrtRuntime;
use coral::util::table;
use coral::workload::VideoSource;

fn main() -> anyhow::Result<()> {
    const SEEDS: u64 = 20;
    let runner = FleetRunner::auto();
    println!(
        "CORAL fleet sweep — all 6 dual-constraint scenarios × {SEEDS} seeds \
         ({} workers)\n",
        runner.workers()
    );

    let stats = fleet_sweep(&DUAL_SCENARIOS, SEEDS, &runner);
    let mut rows = Vec::new();
    for st in &stats {
        rows.push(vec![
            st.scenario.device.name().to_string(),
            st.scenario.model.name().to_string(),
            format!("{}/{}", st.scenario.target_fps, st.scenario.budget_mw),
            format!("{:.0}%", st.feasible as f64 / SEEDS as f64 * 100.0),
            format!("{:.1}", st.mean_first_feasible),
            format!("{:.0}s", st.mean_cost_s),
        ]);
    }
    print!(
        "{}",
        table::render(
            &["device", "model", "target/budget", "feasible", "iters to hit", "search cost"],
            &rows
        )
    );

    // --- Measurement cache: repeat passes replay from the store --------
    // The same sweep through `CachedEnv` over one shared store: the
    // first pass pays for every unseen window (misses), the second pass
    // replays the whole sweep as hits at zero measurement cost — same
    // outcomes, no boards touched. EXPERIMENTS.md §Measurement cache.
    const CACHED_SEEDS: u64 = 8;
    let cached_scenarios = &DUAL_SCENARIOS[..3];
    let store = CacheStore::new();
    let p1 = fleet_sweep_cached(cached_scenarios, CACHED_SEEDS, &runner, &store);
    let after_p1 = store.stats();
    let p2 = fleet_sweep_cached(cached_scenarios, CACHED_SEEDS, &runner, &store);
    let after_p2 = store.stats();
    println!(
        "\ncached repeat sweep ({} scenarios × {CACHED_SEEDS} seeds, shared store):",
        cached_scenarios.len()
    );
    println!(
        "  pass 1: {} real windows (misses), mean cost {:.0}s/scenario",
        after_p1.misses,
        p1.iter().map(|s| s.mean_cost_s).sum::<f64>() / p1.len() as f64
    );
    println!(
        "  pass 2: {} new windows, {} hits, mean cost {:.0}s/scenario — \
         {:.0} simulated seconds of measurement saved",
        after_p2.misses - after_p1.misses,
        after_p2.hits - after_p1.hits,
        p2.iter().map(|s| s.mean_cost_s).sum::<f64>() / p2.len() as f64,
        after_p2.cost_saved_s
    );
    assert!(
        p2.iter().all(|s| s.mean_cost_s == 0.0),
        "every pass-2 window must hit the store"
    );

    // --- Router demo: one box serving all three models -----------------
    match Manifest::load(&artifacts_dir()) {
        Err(e) => println!("\n(router demo skipped — no artifacts: {e})"),
        Ok(manifest) => {
            println!("\nRouter demo: mixed traffic across all three detectors");
            let rt = PjrtRuntime::cpu()?;
            let mut router = Router::new();
            let mut side = 0;
            for model in ModelKind::ALL {
                let m = rt.load_model(&manifest, model)?;
                side = m.input_side();
                router.register(
                    model,
                    Server::new(
                        m,
                        ServerConfig {
                            concurrency: 1,
                            batcher: BatcherConfig {
                                max_batch: 2,
                                max_wait: Duration::from_millis(4),
                            },
                        },
                    ),
                );
            }
            let video = VideoSource::new(side, 30, 3);
            let total = 45u64;
            let mut sent = 0u64;
            let mut done = 0u64;
            while done < total {
                if sent < total {
                    let model = ModelKind::ALL[(sent % 3) as usize];
                    if router.route(model, sent, video.frame(sent as usize))? {
                        sent += 1;
                    }
                }
                done += router.tick().len() as u64;
                if done < total {
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
            for (model, count) in router.shutdown() {
                println!("  {model}: {count} frames served");
            }
        }
    }
    Ok(())
}
