//! Fleet sweep: CORAL across the whole fleet — every (device, model)
//! dual-constraint scenario × many seeds — reporting convergence
//! statistics (feasibility rate, iterations-to-first-feasible, search
//! cost), plus a multi-model Router demo when artifacts are present.
//!
//! ```sh
//! cargo run --release --example fleet_sweep
//! ```

use std::time::Duration;

use coral::coordinator::{BatcherConfig, Router, Server, ServerConfig};
use coral::device::Device;
use coral::experiments::scenarios::DUAL_SCENARIOS;
use coral::models::{artifacts_dir, Manifest, ModelKind};
use coral::optimizer::{Constraints, CoralOptimizer, Optimizer};
use coral::runtime::PjrtRuntime;
use coral::util::table;
use coral::workload::VideoSource;

fn main() -> anyhow::Result<()> {
    const SEEDS: u64 = 20;
    println!("CORAL fleet sweep — all 6 dual-constraint scenarios × {SEEDS} seeds\n");

    let mut rows = Vec::new();
    for s in DUAL_SCENARIOS {
        let cons = Constraints::dual(s.target_fps, s.budget_mw);
        let mut feasible = 0u64;
        let mut first_feasible_iters = Vec::new();
        let mut cost_s = 0.0;
        for seed in 0..SEEDS {
            let mut dev = Device::new(s.device, s.model, 0xF1EE7 + seed);
            let mut opt = CoralOptimizer::new(dev.space().clone(), cons, seed);
            let mut first = None;
            for i in 0..10 {
                let cfg = opt.propose();
                let m = dev.run(cfg);
                opt.observe(cfg, m.throughput_fps, m.power_mw);
                if first.is_none() && cons.feasible(m.throughput_fps, m.power_mw) {
                    first = Some(i + 1);
                }
            }
            if opt.best().map(|b| b.feasible).unwrap_or(false) {
                feasible += 1;
            }
            if let Some(f) = first {
                first_feasible_iters.push(f as f64);
            }
            cost_s += dev.sim_clock_s();
        }
        let mean_first = if first_feasible_iters.is_empty() {
            f64::NAN
        } else {
            first_feasible_iters.iter().sum::<f64>() / first_feasible_iters.len() as f64
        };
        rows.push(vec![
            s.device.name().to_string(),
            s.model.name().to_string(),
            format!("{}/{}", s.target_fps, s.budget_mw),
            format!("{:.0}%", feasible as f64 / SEEDS as f64 * 100.0),
            format!("{mean_first:.1}"),
            format!("{:.0}s", cost_s / SEEDS as f64),
        ]);
    }
    print!(
        "{}",
        table::render(
            &["device", "model", "target/budget", "feasible", "iters to hit", "search cost"],
            &rows
        )
    );

    // --- Router demo: one box serving all three models -----------------
    match Manifest::load(&artifacts_dir()) {
        Err(e) => println!("\n(router demo skipped — no artifacts: {e})"),
        Ok(manifest) => {
            println!("\nRouter demo: mixed traffic across all three detectors");
            let rt = PjrtRuntime::cpu()?;
            let mut router = Router::new();
            let mut side = 0;
            for model in ModelKind::ALL {
                let m = rt.load_model(&manifest, model)?;
                side = m.input_side();
                router.register(
                    model,
                    Server::new(
                        m,
                        ServerConfig {
                            concurrency: 1,
                            batcher: BatcherConfig {
                                max_batch: 2,
                                max_wait: Duration::from_millis(4),
                            },
                        },
                    ),
                );
            }
            let video = VideoSource::new(side, 30, 3);
            let total = 45u64;
            let mut sent = 0u64;
            let mut done = 0u64;
            while done < total {
                if sent < total {
                    let model = ModelKind::ALL[(sent % 3) as usize];
                    if router.route(model, sent, video.frame(sent as usize))? {
                        sent += 1;
                    }
                }
                done += router.tick().len() as u64;
                if done < total {
                    std::thread::sleep(Duration::from_micros(200));
                }
            }
            for (model, count) in router.shutdown() {
                println!("  {model}: {count} frames served");
            }
        }
    }
    Ok(())
}
