//! Quickstart: run CORAL against the simulated Jetson Xavier NX under the
//! paper's dual constraint (30 fps, 6.5 W) and watch it converge in 10
//! iterations — no artifacts or PJRT needed.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use coral::device::{Device, DeviceKind};
use coral::models::ModelKind;
use coral::optimizer::{Constraints, CoralOptimizer, Optimizer};

fn main() {
    let device = DeviceKind::XavierNx;
    let model = ModelKind::Yolo;
    let cons = Constraints::dual(30.0, 6500.0); // paper §IV-B
    println!("CORAL quickstart — {device} / {model}, target 30 fps, budget 6.5 W\n");

    let mut dev = Device::new(device, model, 42);
    let mut opt = CoralOptimizer::new(dev.space().clone(), cons, 42);

    for i in 0..10 {
        let cfg = opt.propose();
        let m = dev.run(cfg);
        opt.observe(cfg, m.throughput_fps, m.power_mw);
        println!(
            "it{i:>2}: {cfg} -> {:5.1} fps @ {:4.2} W {}",
            m.throughput_fps,
            m.power_mw / 1000.0,
            if cons.feasible(m.throughput_fps, m.power_mw) { "  << feasible" } else { "" }
        );
    }

    let best = opt.best().expect("observations recorded");
    println!(
        "\nchosen: {}\n        {:.1} fps @ {:.2} W  (feasible: {})",
        best.config,
        best.throughput_fps,
        best.power_mw / 1000.0,
        best.feasible
    );
    println!(
        "search cost: {:.0} simulated seconds — vs {:.1} simulated hours for an\n\
         exhaustive ORACLE sweep of {} configurations.",
        dev.sim_clock_s(),
        dev.space().raw_size() as f64 * 7.0 / 3600.0,
        dev.space().raw_size()
    );
}
