//! Quickstart: run CORAL against the simulated Jetson Xavier NX under the
//! paper's dual constraint (30 fps, 6.5 W) and watch it converge in 10
//! iterations — no artifacts or PJRT needed. The drive loop is the
//! canonical `control::ControlLoop`, stepped manually for per-iteration
//! printing.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use coral::control::{ControlLoop, Environment, SimEnv};
use coral::device::{Device, DeviceKind};
use coral::models::ModelKind;
use coral::optimizer::{Constraints, CoralOptimizer};

fn main() {
    let device = DeviceKind::XavierNx;
    let model = ModelKind::Yolo;
    let cons = Constraints::dual(30.0, 6500.0); // paper §IV-B
    println!("CORAL quickstart — {device} / {model}, target 30 fps, budget 6.5 W\n");

    let dev = Device::new(device, model, 42);
    let opt = CoralOptimizer::new(dev.space().clone(), cons, 42);
    let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons, 10);

    while !cl.done() {
        let step = cl.step();
        println!(
            "it{:>2}: {} -> {:5.1} fps @ {:4.2} W {}",
            step.iter,
            step.config,
            step.measured.throughput_fps,
            step.measured.power_mw / 1000.0,
            if step.feasible { "  << feasible" } else { "" }
        );
    }

    let out = cl.outcome();
    let best = out.best.expect("observations recorded");
    println!(
        "\nchosen: {}\n        {:.1} fps @ {:.2} W  (feasible: {})",
        best.config,
        best.throughput_fps,
        best.power_mw / 1000.0,
        best.feasible
    );
    let raw = cl.env().space().raw_size();
    println!(
        "search cost: {:.0} simulated seconds — vs {:.1} simulated hours for an\n\
         exhaustive ORACLE sweep of {} configurations.",
        out.cost_s,
        raw as f64 * 7.0 / 3600.0,
        raw
    );
}
