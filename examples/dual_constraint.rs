//! Dual-constraint showdown: run the paper's full method lineup on one
//! scenario and print the Fig 5/6-style comparison. Every method drives
//! through the canonical `control::ControlLoop` (via
//! `experiments::runner::run_method`). Scenario selectable via env (no
//! CLI parsing in examples):
//!
//! ```sh
//! cargo run --release --example dual_constraint                 # NX / YOLO
//! CORAL_DEVICE=orin CORAL_MODEL=retinanet \
//!   cargo run --release --example dual_constraint               # hardest case
//! ```

use coral::device::DeviceKind;
use coral::experiments::runner::{aggregate, run_method, MethodKind};
use coral::experiments::scenarios::DUAL_SCENARIOS;
use coral::models::ModelKind;
use coral::optimizer::Constraints;
use coral::util::table;

fn main() {
    let device = std::env::var("CORAL_DEVICE")
        .ok()
        .and_then(|s| DeviceKind::parse(&s))
        .unwrap_or(DeviceKind::XavierNx);
    let model = std::env::var("CORAL_MODEL")
        .ok()
        .and_then(|s| ModelKind::parse(&s))
        .unwrap_or(ModelKind::Yolo);
    let s = DUAL_SCENARIOS
        .iter()
        .find(|s| s.device == device && s.model == model)
        .expect("scenario");
    let cons = Constraints::dual(s.target_fps, s.budget_mw);

    println!(
        "Dual-constraint scenario: {device} / {model} — target {} fps, budget {} mW",
        s.target_fps, s.budget_mw
    );
    println!("(10 online iterations per method, 10 seeds; ORACLE = exhaustive)\n");

    let mut rows = Vec::new();
    for kind in MethodKind::PAPER_LINEUP {
        let seeds = if kind == MethodKind::Oracle { 1 } else { 10 };
        let outs: Vec<_> = (0..seeds)
            .map(|i| run_method(kind, device, model, cons, 0xE0 + i))
            .collect();
        let a = aggregate(&outs);
        rows.push(vec![
            a.method.to_string(),
            format!("{:.1}", a.mean_fps),
            format!("{:.2}", a.mean_mw / 1000.0),
            format!("{:.0}%", a.feasible_rate * 100.0),
            format!("{:.0}", a.mean_online_windows),
            a.offline_windows.to_string(),
        ]);
    }
    print!(
        "{}",
        table::render(
            &["method", "fps", "W", "meets both", "online", "offline"],
            &rows
        )
    );
    println!(
        "\npaper's story: CORAL + ORACLE satisfy both constraints; ALERT overshoots\n\
         the power budget; ALERT-Online's random trials miss the narrow feasible\n\
         region; presets fail one constraint each."
    );
}
