//! Variant switching: accuracy joins the throughput–power trade-off as
//! a seventh search dimension.
//!
//! The paper's search space is pure hardware: DVFS rails, cores,
//! concurrency, batch. Every rung holds the model fixed, so when the
//! power budget can't carry the full detector at the target rate the
//! only answers are "miss the target" or "overdraw". Real edge stacks
//! have a third lever — serve a cheaper *variant* of the same model
//! (INT8 quantization, reduced input resolution, depth scaling) and pay
//! in accuracy instead of watts. `VariantManifest` makes that ladder
//! explicit: an ordered list of `ModelVariant`s, each with a modeled
//! mAP and perf/power/memory multipliers, rung 0 always the full model.
//!
//! `Device::with_variants` opens `Dim::Variant` on the config grid,
//! `Measured::accuracy` reports the mAP the window served, and
//! `Constraints::with_min_accuracy` makes the floor a fourth
//! satisfaction clause — so CORAL co-optimizes throughput, power, and
//! accuracy through the same control loop, unchanged.
//!
//! The run picks an `ACCURACY_SCENARIOS` entry where the full model is
//! *infeasible* (no hardware config reaches the target inside budget),
//! shows which manifest rungs open a feasible region, and lets CORAL
//! find one. `bench_variants` asserts the same story across all four
//! scenarios plus the arbitrated-tenant leg (EXPERIMENTS.md §Accuracy
//! trade-off).
//!
//! ```sh
//! cargo run --release --example variant_switch
//! ```

use coral::control::ControlLoop;
use coral::experiments::scenarios::{AccuracyScenario, ACCURACY_SCENARIOS};
use coral::optimizer::CoralOptimizer;
use coral::util::table;

const SEED: u64 = 42;
const BUDGET: usize = 40;

fn main() {
    let s = AccuracyScenario::by_name("acc-nx-frcnn").expect("scenario exists");
    println!(
        "CORAL with the variant axis open — scenario {} ({} also available)\n",
        s.name,
        ACCURACY_SCENARIOS
            .iter()
            .filter(|o| o.name != s.name)
            .map(|o| o.name)
            .collect::<Vec<_>>()
            .join(", ")
    );
    let cons = s.constraints();
    println!("{}/{} — {}", s.device, s.model, cons.describe());

    // The degradation ladder, with the noise-free feasible-region size
    // each rung opens under all three clauses. Rung 0 is the full
    // model: its zero is the whole point of the scenario.
    let manifest = s.manifest();
    let space = s.device.space().with_variant_axis(manifest.len());
    let grid = space.enumerate();
    let mut rows = Vec::new();
    for (i, v) in manifest.variants().iter().enumerate() {
        let feasible = grid
            .iter()
            .filter(|c| c.variant == i as u32 && s.config_feasible(c))
            .count();
        rows.push(vec![
            i.to_string(),
            v.label(),
            format!("{:.1}", v.accuracy),
            format!("x{:.2}", v.perf_mult),
            format!("x{:.2}", v.power_mult),
            format!("x{:.2}", v.mem_mult),
            feasible.to_string(),
        ]);
    }
    println!();
    print!(
        "{}",
        table::render(
            &["idx", "variant", "mAP", "perf", "power", "mem", "feasible cfgs"],
            &rows
        )
    );

    // CORAL over the 7-dim space: the variant index is one more
    // discrete coordinate under the same covariance guide.
    let env = s.env(SEED);
    let opt = CoralOptimizer::new(env.space().clone(), cons, SEED);
    let mut cl = ControlLoop::with_budget(env, opt, cons, BUDGET);
    let out = cl.run();
    let best = out.best.expect("simulated windows always measure");
    let v = manifest.get(best.config.variant);
    println!(
        "\nbest after {} windows: {} ({})\n  -> {:.1} fps @ {:.0} mW, mAP {:.1}, feasible={}",
        out.iters,
        best.config,
        v.label(),
        best.throughput_fps,
        best.power_mw,
        best.accuracy,
        best.feasible
    );
    println!(
        "\nThe full detector cannot reach {:.0} fps inside {:.1} W on this board — \
         every feasible config lives on a degraded rung that still clears the \
         {:.1}-mAP floor. Accuracy is spent like power: deliberately, and only \
         down to the constraint.",
        s.target_fps,
        s.budget_mw / 1000.0,
        s.min_accuracy
    );
}
