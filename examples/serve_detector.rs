//! End-to-end serving driver (the repo's E2E validation, DESIGN.md §5).
//!
//! Proves all three layers compose on a real workload through the
//! closed-loop engine:
//!
//! 1. `control::LiveEnv` loads the AOT-compiled JAX/Pallas YOLO detector
//!    (`make artifacts`) behind the full coordinator (batcher → worker
//!    pool → PJRT),
//! 2. `control::ControlLoop` runs CORAL *live*: each proposal applies
//!    its concurrency level to the real worker pool, throughput is
//!    sampled from served traffic with the paper's warm-up discipline
//!    through the event-driven serving pump (zero busy-wait: the
//!    pump's wakeups — printed at the end — are bounded by completions
//!    and batcher deadline fires, never wall-clock), power comes from
//!    the Jetson device model, and
//! 3. without artifacts the environment degrades gracefully to
//!    sim-backed measurement, so this example always runs.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_detector
//! ```
//!
//! The recorded run lives in EXPERIMENTS.md §E2E.

use std::time::Duration;

use coral::control::{ControlLoop, LiveEnv};
use coral::coordinator::{BatcherConfig, ServerConfig};
use coral::device::DeviceKind;
use coral::models::ModelKind;
use coral::optimizer::{Constraints, CoralOptimizer};

fn main() -> anyhow::Result<()> {
    coral::util::logging::init();
    let model = ModelKind::Yolo;
    let device = DeviceKind::XavierNx;
    let cons = Constraints::dual(30.0, 6500.0);

    // --- Layers 1+2: artifacts → PJRT → serving stack (or sim fallback) --
    let env = LiveEnv::auto(
        device,
        model,
        7,
        ServerConfig {
            concurrency: 1,
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) },
        },
    )
    .frames_per_sample(12);
    if env.is_live() {
        println!("live serving stack up (PJRT artifacts compiled)");
    } else {
        println!(
            "no PJRT artifacts — degraded to sim-backed measurement \
             (run `make artifacts` for the live path)"
        );
    }

    // --- Layer 3: CORAL in the closed loop -------------------------------
    let opt = CoralOptimizer::new(env.device().space().clone(), cons, 7);
    let mut cl = ControlLoop::with_budget(env, opt, cons, 10);
    println!("CORAL tuning the serving stack ({device} telemetry, 30 fps / 6.5 W):");
    while !cl.done() {
        let step = cl.step();
        let m = step.measured;
        // The window observation: throughput is live-sampled when a
        // server is up (sim-backed otherwise); power is always the
        // device model's.
        print!(
            "  it{:>2}: {}\n        window: {:5.1} fps @ {:4.2} W {}",
            step.iter,
            step.config,
            m.throughput_fps,
            m.power_mw / 1000.0,
            if m.failed.is_some() {
                "FAILED"
            } else if step.feasible {
                "ok    "
            } else {
                "infeas"
            },
        );
        match cl.env().last_report() {
            Some(r) => println!(
                " | live CPU: {:5.1} fps, p50 {:5.1} ms, p99 {:5.1} ms, batch {:.2}",
                r.throughput_fps, r.latency_p50_ms, r.latency_p99_ms, r.mean_batch
            ),
            None => println!(),
        }
    }

    let out = cl.outcome();
    let best = out.best.expect("observed");
    println!(
        "\nCORAL chose {} -> {:.1} fps @ {:.2} W (feasible: {})",
        best.config,
        best.throughput_fps,
        best.power_mw / 1000.0,
        best.feasible
    );
    println!(
        "search cost: {:.1} s ({} measurement windows)",
        out.cost_s, out.iters
    );

    // Steady-state serving at the chosen configuration (live mode only).
    let mut env = cl.into_env();
    if let Some(report) = env.steady_state(best.config, 300) {
        println!("steady state (300 frames): {report}");
        println!(
            "pump: {} wake-ups ({} deadline fires) — event-driven, no sleep-polling",
            report.pump_iterations, report.deadline_fires
        );
    }
    let pump_total = env.pump_iterations();
    if let Some(total) = env.shutdown() {
        println!("total served: {total} frames over {pump_total} pump wake-ups");
    }
    Ok(())
}
