//! End-to-end serving driver (the repo's E2E validation, DESIGN.md §5).
//!
//! Proves all three layers compose on a real workload:
//!
//! 1. loads the AOT-compiled JAX/Pallas YOLO detector (`make artifacts`),
//! 2. serves the synthetic traffic video through the full coordinator
//!    (router-less single-model path: batcher → worker pool → PJRT), and
//! 3. runs CORAL *live*: each iteration applies a hardware configuration
//!    (concurrency level takes effect on the real worker pool; DVFS on
//!    the Jetson device model that supplies the power/fps telemetry), and
//!    reports the real serving metrics next to the simulated telemetry.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_detector
//! ```
//!
//! The recorded run lives in EXPERIMENTS.md §E2E.

use std::time::Duration;

use coral::coordinator::{BatcherConfig, Server, ServerConfig};
use coral::device::{Device, DeviceKind};
use coral::models::{artifacts_dir, Manifest, ModelKind};
use coral::optimizer::{Constraints, CoralOptimizer, Optimizer};
use coral::runtime::PjrtRuntime;
use coral::workload::VideoSource;

fn main() -> anyhow::Result<()> {
    coral::util::logging::init();
    let model = ModelKind::Yolo;
    let device = DeviceKind::XavierNx;
    let cons = Constraints::dual(30.0, 6500.0);

    // --- Layer 1+2: AOT artifacts → PJRT executables --------------------
    let manifest = Manifest::load(&artifacts_dir())
        .map_err(|e| anyhow::anyhow!("{e} — run `make artifacts` first"))?;
    let rt = PjrtRuntime::cpu()?;
    println!("PJRT platform: {}", rt.platform());
    let model_rt = rt.load_model(&manifest, model)?;
    let side = model_rt.input_side();
    println!(
        "loaded {} batch variants of {model} ({}x{side} input)\n",
        model_rt.batch_sizes().len(),
        side
    );

    // --- Layer 3: serving stack + device telemetry ----------------------
    let mut server = Server::new(
        model_rt,
        ServerConfig {
            concurrency: 1,
            batcher: BatcherConfig { max_batch: 4, max_wait: Duration::from_millis(5) },
        },
    );
    let mut video = VideoSource::new(side, 30, 0xCAFE);
    let mut jetson = Device::new(device, model, 7);
    let mut opt = CoralOptimizer::new(jetson.space().clone(), cons, 7);

    println!("CORAL tuning the live server ({device} telemetry, 30 fps / 6.5 W):");
    const FRAMES_PER_WINDOW: u64 = 60;
    for i in 0..10 {
        let cfg = opt.propose();
        // Apply the configuration: concurrency drives the real worker
        // pool; DVFS drives the Jetson device model.
        server.set_concurrency(cfg.concurrency as usize);
        let m = jetson.run(cfg);
        let report = server.run_closed_loop(&mut video, FRAMES_PER_WINDOW, 8)?;
        opt.observe(cfg, m.throughput_fps, m.power_mw);
        println!(
            "  it{i:>2}: {cfg}\n        jetson: {:5.1} fps @ {:4.2} W {} | local CPU: {:5.1} fps, p50 {:5.1} ms, p99 {:5.1} ms, batch {:.2}",
            m.throughput_fps,
            m.power_mw / 1000.0,
            if m.failed.is_some() {
                "FAILED"
            } else if cons.feasible(m.throughput_fps, m.power_mw) {
                "ok    "
            } else {
                "infeas"
            },
            report.throughput_fps,
            report.latency_p50_ms,
            report.latency_p99_ms,
            report.mean_batch,
        );
    }

    let best = opt.best().expect("observed");
    println!(
        "\nCORAL chose {} -> {:.1} fps @ {:.2} W (feasible: {})",
        best.config,
        best.throughput_fps,
        best.power_mw / 1000.0,
        best.feasible
    );

    // Steady-state serving at the chosen configuration.
    server.set_concurrency(best.config.concurrency as usize);
    let report = server.run_closed_loop(&mut video, 300, 8)?;
    println!("steady state (300 frames): {report}");
    println!("total served: {}", server.shutdown());
    Ok(())
}
