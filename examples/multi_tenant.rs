//! Multi-tenant power-budget arbitration: three detectors sharing one
//! simulated Xavier NX under a single 21 W envelope.
//!
//! Per-model tuning (the PolyThrottle regime) breaks down on a shared
//! box: each controller honestly meets *its own* budget while the box
//! blows the shared one. `control::TenantArbiter` fixes this by
//! splitting the envelope into per-tenant sub-budgets every round —
//! here with the water-filling policy, so tenants already holding a
//! feasible configuration donate their slack to the ones still
//! searching — and driving one CORAL `ControlLoop` per tenant against
//! its sub-budget, thread-parallel with byte-identical-to-sequential
//! trajectories.
//!
//! The run prints each arbitration round, then the same tenants as
//! unarbitrated independent controllers for the aggregate-overshoot
//! comparison (`bench_tenants` scores the same comparison across all
//! scenarios and policies).
//!
//! ```sh
//! cargo run --release --example multi_tenant
//! ```

use coral::control::{BudgetPolicy, Environment, TenantArbiter};
use coral::experiments::scenarios::{TenantScenario, MULTI_TENANT_SCENARIOS};
use coral::util::table;

const ROUNDS: usize = 4;
const SEED: u64 = 42;

fn run(label: &str, s: &TenantScenario, arb: &mut TenantArbiter) -> f64 {
    println!(
        "\n{label}: {} tenants on one {} box, {:.1} W global envelope",
        s.tenants.len(),
        s.device,
        s.global_budget_mw / 1000.0
    );
    let mut rows = Vec::new();
    for _ in 0..ROUNDS {
        let report = arb.run_round();
        for t in &report.tenants {
            rows.push(vec![
                report.round.to_string(),
                t.name.to_string(),
                format!("{:.2}", t.sub_budget_mw / 1000.0),
                format!("{:.1}", t.chosen.throughput_fps),
                format!("{:.2}", t.chosen.power_mw / 1000.0),
                if t.fell_back {
                    "floor".into()
                } else if t.feasible {
                    "ok".into()
                } else {
                    "infeas".into()
                },
                t.restarts.to_string(),
            ]);
        }
    }
    print!(
        "{}",
        table::render(
            &["round", "tenant", "budget W", "fps", "power W", "state", "restarts"],
            &rows
        )
    );
    let max_over = arb
        .history()
        .iter()
        .map(|r| r.overshoot_mw)
        .fold(0.0, f64::max);
    println!(
        "aggregate power, last round: {:.2} W of {:.2} W  (max overshoot {:.2} W, \
         search cost {:.0} s)",
        arb.history().last().expect("rounds ran").aggregate_power_mw / 1000.0,
        s.global_budget_mw / 1000.0,
        max_over / 1000.0,
        arb.cost_s()
    );
    max_over
}

fn main() {
    let s = TenantScenario::by_name("nx-triple").expect("scenario exists");
    println!(
        "CORAL multi-tenant arbitration — scenario {} ({} also available)",
        s.name,
        MULTI_TENANT_SCENARIOS
            .iter()
            .filter(|o| o.name != s.name)
            .map(|o| o.name)
            .collect::<Vec<_>>()
            .join(", ")
    );

    let mut arb = s.arbiter(BudgetPolicy::WaterFill, SEED);
    let arb_over = run("arbitrated (water-filling)", s, &mut arb);

    let mut ind = s.independent(SEED);
    let ind_over = run("independent controllers (unarbitrated baseline)", s, &mut ind);

    println!(
        "\nverdict: arbitrated max overshoot {:.2} W vs independent {:.2} W — the shared \
         envelope needs an arbiter, not N honest per-model controllers",
        arb_over / 1000.0,
        ind_over / 1000.0
    );
}
