//! Open-loop load: arrival-driven measurement, p99 latency SLOs, and
//! `max_batch` as a sixth search dimension.
//!
//! Closed-loop measurement (submit → wait) answers "how fast can this
//! config go"; it cannot represent heavy traffic from external users,
//! where arrivals do not wait for the device. Here every measurement
//! window queues against an `ArrivalProfile`'s offered load: served
//! throughput pins at the arrival rate, the queueing tail lands in
//! `Measured::p99_latency_ms`, and a saturated config *sheds* (p99 → ∞).
//! `Constraints::with_latency_slo` makes that tail a third satisfaction
//! clause next to the paper's throughput/power pair.
//!
//! The run drives CORAL over the full 6-dim space (the batch axis opened
//! to 1/2/4 — the batching+DVFS optimum is coupled, so `max_batch` is
//! a search dimension, not a fixed coordinator knob), then ramps the
//! offered rate and reports the shed point — the highest load each
//! policy still serves inside SLO+power — for CORAL's pick, the full
//! valid space, and both manufacturer presets. `bench_load` asserts the
//! same story across all `LOAD_SCENARIOS` (EXPERIMENTS.md §Open-loop
//! load).
//!
//! ```sh
//! cargo run --release --example open_loop
//! ```

use coral::control::{ControlLoop, SimEnv};
use coral::device::{failure, Device};
use coral::experiments::scenarios::{LoadScenario, LOAD_SCENARIOS};
use coral::optimizer::CoralOptimizer;
use coral::util::table;

const SEED: u64 = 42;
const BUDGET: usize = 10;
const BATCH_CAPS: &[u32] = LoadScenario::BATCH_CAPS;

fn main() {
    let s = LoadScenario::by_name("load-nx-yolo-steady").expect("scenario exists");
    println!(
        "CORAL under open-loop load — scenario {} ({} also available)\n",
        s.name,
        LOAD_SCENARIOS
            .iter()
            .filter(|o| o.name != s.name)
            .map(|o| o.name)
            .collect::<Vec<_>>()
            .join(", ")
    );
    let cons = s.constraints();
    println!(
        "{}/{} under '{}' arrivals at {:.0} fps — {}",
        s.device,
        s.model,
        s.profile,
        s.base_rate_fps,
        cons.describe()
    );

    // One simulated board with the batch axis open; every window this
    // environment measures queues against the scenario's offered load.
    let dev = Device::new(s.device, s.model, SEED).with_batch_caps(BATCH_CAPS.to_vec());
    let space = dev.space().clone();
    let env = SimEnv::new(dev).under_load(s.arrival(SEED));
    let opt = CoralOptimizer::new(space.clone(), cons, SEED);
    let mut cl = ControlLoop::with_budget(env, opt, cons, BUDGET);
    let out = cl.run();
    let best = out.best.expect("simulated windows always measure");
    println!(
        "\nbest after {} windows: {}\n  -> {:.1} fps served @ {:.0} mW, p99 {:.1} ms, \
         feasible={}",
        out.iters, best.config, best.throughput_fps, best.power_mw, best.p99_latency_ms,
        best.feasible
    );

    // Shed ramp on the noise-free surface: climb the offered rate until
    // the SLO+power pair is unsatisfiable.
    let step = s.base_rate_fps * 0.25;
    let valid6: Vec<_> = space
        .enumerate()
        .into_iter()
        .filter(|c| failure::check(s.device, s.model, c).is_none())
        .collect();
    let valid5: Vec<_> = valid6.iter().filter(|c| c.max_batch == 1).copied().collect();
    let rows = vec![
        vec!["coral best".into(), format!("{:.1}", s.shed_point_fps(&[best.config], step))],
        vec!["oracle 6-dim (batch open)".into(), format!("{:.1}", s.shed_point_fps(&valid6, step))],
        vec!["oracle 5-dim (batch=1)".into(), format!("{:.1}", s.shed_point_fps(&valid5, step))],
        vec![
            "preset max-power".into(),
            format!("{:.1}", s.shed_point_fps(&[s.device.preset_max_power()], step)),
        ],
        vec![
            "preset default".into(),
            format!("{:.1}", s.shed_point_fps(&[s.device.preset_default()], step)),
        ],
    ];
    println!();
    print!("{}", table::render(&["policy", "shed point (fps)"], &rows));
    println!(
        "\nBatching amortizes launches (sublinear throughput gain) at a latency and \
         power cost: the 6-dim oracle outlasts every fixed-batch policy, and the \
         queueing tail — not raw capacity — is what gives out first."
    );
}
