//! Chaos fleet: CORAL surviving a deterministic fault schedule.
//!
//! `control::chaos::ChaosEnv` decorates any `Environment` with a
//! seeded schedule of faults — member dropout and rejoin, thermal
//! throttling (enable / heat soak / ambient shift), sensor-glitch
//! bursts (NaN and stuck-at readings), and power-budget steps — and
//! keeps per-event recovery accounting: the window each event fired,
//! and the first window at or after it whose measurement satisfied the
//! then-current constraints again.
//!
//! The run drives CORAL (search → drift-watched hold → re-search)
//! through every `CHAOS_SCENARIOS` family on the mixed NX+Orin pair,
//! prints the recovery table, then replays the combined schedule
//! against a static all-max preset to show why a non-adaptive baseline
//! never comes back after a budget step. `bench_chaos` scores the same
//! comparison with assertions (EXPERIMENTS.md §Chaos fleet).
//!
//! ```sh
//! cargo run --release --example chaos_fleet
//! ```

use coral::control::{drive_coral, drive_static, Environment};
use coral::experiments::scenarios::CHAOS_SCENARIOS;
use coral::util::table;

const SEED: u64 = 42;

fn main() {
    println!("CORAL chaos fleet — deterministic fault schedules over the NX+Orin pair\n");

    let mut rows = Vec::new();
    for s in &CHAOS_SCENARIOS {
        let env = s.chaos(SEED);
        println!(
            "{}: {} windows, {} scheduled events",
            s.name,
            s.windows,
            env.schedule().len()
        );
        let done = drive_coral(env, s.constraints(), SEED, s.windows);
        for r in done.recoveries() {
            rows.push(vec![
                s.name.to_string(),
                r.label.clone(),
                r.at_window.to_string(),
                r.recovered_at.map_or("never".to_string(), |w| w.to_string()),
                r.windows().map_or("∞".to_string(), |w| w.to_string()),
            ]);
        }
        println!(
            "  mean recovery {:.1} windows, all recovered: {}\n",
            done.mean_recovery_windows(),
            done.all_recovered()
        );
    }
    print!(
        "{}",
        table::render(&["scenario", "event", "at window", "recovered at", "windows"], &rows)
    );

    // --- Baseline: a static all-max preset through the combined schedule.
    let s = &CHAOS_SCENARIOS[3];
    let env = s.chaos(SEED);
    let cfg = env.space().max_config();
    let done = drive_static(env, cfg, s.windows);
    println!(
        "\nstatic all-max baseline on {}: mean recovery {} windows, all recovered: {} — \
         a fixed preset cannot re-enter the feasible region once a budget step moves it, \
         while CORAL re-searches its way back",
        s.name,
        if done.mean_recovery_windows().is_finite() {
            format!("{:.1}", done.mean_recovery_windows())
        } else {
            "∞".to_string()
        },
        done.all_recovered()
    );
}
