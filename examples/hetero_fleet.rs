//! Heterogeneous fleet: one CORAL instance tuning a mixed Xavier NX +
//! Orin Nano fleet through the normalized rank-fraction grid.
//!
//! The paper evaluates one device class at a time, and raw-frequency
//! features do not transfer between classes (an Orin GPU "step" is a
//! different number of MHz than an NX one). `device::NormSpace` encodes
//! every dimension as its rank fraction in `[0, 1]`; the fleet
//! environment decodes each proposal onto every member's native grid, so
//! a single optimizer — unchanged, behind the same `Optimizer` trait —
//! searches one surface that spans both boards.
//!
//! The run drives the shared search, prints the decoded per-member
//! allocation, then runs the per-device independent baseline (one CORAL
//! per board, same relaxation, N× the measurement cost) for comparison.
//! `bench_hetero` scores the same comparison across all
//! `HETERO_SCENARIOS` (EXPERIMENTS.md §Heterogeneous fleets).
//!
//! ```sh
//! cargo run --release --example hetero_fleet
//! ```

use coral::control::{ControlLoop, Environment, SimEnv};
use coral::device::Device;
use coral::experiments::scenarios::{HeteroScenario, HETERO_SCENARIOS};
use coral::optimizer::CoralOptimizer;
use coral::util::table;

const SEED: u64 = 42;
const BUDGET: usize = 10;

fn main() {
    let s = HeteroScenario::by_name("hetero-yolo-pair").expect("scenario exists");
    println!(
        "CORAL heterogeneous fleet — scenario {} ({} also available)\n",
        s.name,
        HETERO_SCENARIOS
            .iter()
            .filter(|o| o.name != s.name)
            .map(|o| o.name)
            .collect::<Vec<_>>()
            .join(", ")
    );

    // --- Shared: one CORAL over the normalized grid, all boards per window.
    let fleet = s.fleet(SEED);
    let cons = s.constraints();
    let grid = fleet.space().clone();
    println!(
        "shared search: fleet-mean target {} fps, fleet-mean budget {} mW, \
         {} boards measured per window",
        s.target_fps,
        s.budget_mw,
        fleet.len()
    );
    let opt = CoralOptimizer::new(grid.clone(), cons, SEED);
    let mut cl = ControlLoop::with_budget(fleet, opt, cons, BUDGET);
    let out = cl.run();
    let best = out.best.expect("simulated windows always measure");
    let fleet = cl.into_env();
    println!(
        "  chosen {} -> fleet mean {:.1} fps @ {:.0} mW, feasible={} \
         (cost {:.0} s)\n",
        grid.describe(&best.config),
        best.throughput_fps,
        best.power_mw,
        best.feasible,
        out.cost_s
    );
    let ns = fleet.norm().expect("mixed fleet is normalized");
    let mut rows = Vec::new();
    for (i, native) in fleet.decoded(best.config).iter().enumerate() {
        rows.push(vec![
            format!("{i}"),
            s.devices[i].name().to_string(),
            ns.members()[i].describe(native),
        ]);
    }
    print!(
        "{}",
        table::render(&["member", "device", "decoded native configuration"], &rows)
    );

    // --- Baseline: independent per-device CORALs (N searches, N× cost).
    println!("\nindependent baseline: one CORAL per board, same relaxed constraints");
    let mut all_feasible = true;
    let mut total_cost = 0.0;
    for (i, &kind) in s.devices.iter().enumerate() {
        let cons_i = s.member_constraints(i);
        let dev = Device::new(kind, s.model, SEED + i as u64);
        let opt = CoralOptimizer::new(dev.space().clone(), cons_i, SEED + i as u64);
        let mut cl = ControlLoop::with_budget(SimEnv::new(dev), opt, cons_i, BUDGET);
        let out = cl.run();
        let b = out.best.expect("simulated windows always measure");
        all_feasible &= b.feasible;
        total_cost += out.cost_s;
        println!(
            "  board {i} ({kind}): {:.1} fps @ {:.0} mW, feasible={} (cost {:.0} s)",
            b.throughput_fps, b.power_mw, b.feasible, out.cost_s
        );
    }
    println!(
        "\nverdict: shared CORAL feasible={} at {:.0} s of measurement vs independent \
         all-feasible={} at {:.0} s — the normalized encoding buys one search for the \
         whole fleet instead of one per device class",
        best.feasible, out.cost_s, all_feasible, total_cost
    );
}
